//! Artifact registry: parses `artifacts/manifest.json` (written by
//! python/compile/aot.py) and resolves kernel variants by kind/parameters.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::Value;

/// Metadata of one AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub file: PathBuf,
    pub input_shapes: Vec<Vec<usize>>,
    pub n_outputs: usize,
    /// Flat string map of the python-side params (n, lonum, precision, ...).
    pub params: BTreeMap<String, String>,
}

impl ArtifactMeta {
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.params.get(key).and_then(|v| v.parse().ok())
    }

    pub fn param(&self, key: &str) -> Option<&str> {
        self.params.get(key).map(|s| s.as_str())
    }
}

/// CNN export metadata (weights dir + architecture).
#[derive(Clone, Debug)]
pub struct CnnMeta {
    pub dir: PathBuf,
    pub test_accuracy: f64,
    /// (name, c_in, c_out) for each conv layer.
    pub conv_specs: Vec<(String, usize, usize)>,
    pub img: usize,
    pub num_classes: usize,
}

/// The full artifact bundle: directory + manifest.
#[derive(Clone, Debug)]
pub struct ArtifactBundle {
    pub dir: PathBuf,
    pub lonum: usize,
    by_name: BTreeMap<String, ArtifactMeta>,
    pub cnn: Option<CnnMeta>,
}

impl ArtifactBundle {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactBundle> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "{}: {e} (run `make artifacts` first)",
                manifest_path.display()
            ))
        })?;
        let root = Value::parse(&text)?;
        let lonum = root.get("lonum")?.as_usize()?;
        let mut by_name = BTreeMap::new();
        for art in root.get("artifacts")?.as_array()? {
            let name = art.get("name")?.as_str()?.to_string();
            let file = dir.join(art.get("file")?.as_str()?);
            if !file.exists() {
                return Err(Error::Artifact(format!(
                    "manifest references missing file {}",
                    file.display()
                )));
            }
            let mut input_shapes = Vec::new();
            for inp in art.get("inputs")?.as_array()? {
                let dims = inp
                    .get("shape")?
                    .as_array()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                input_shapes.push(dims);
            }
            let mut params = BTreeMap::new();
            if let Some(p) = art.get_opt("params") {
                for (k, v) in p.as_object()? {
                    let s = match v {
                        Value::String(s) => s.clone(),
                        Value::Number(x) => {
                            if x.fract() == 0.0 {
                                format!("{}", *x as i64)
                            } else {
                                format!("{x}")
                            }
                        }
                        Value::Bool(b) => b.to_string(),
                        _ => continue,
                    };
                    params.insert(k.clone(), s);
                }
            }
            by_name.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    kind: art.get("kind")?.as_str()?.to_string(),
                    file,
                    input_shapes,
                    n_outputs: art.get("n_outputs")?.as_usize()?,
                    params,
                },
            );
        }
        let cnn = match root.get_opt("cnn") {
            Some(c) => {
                let mut conv_specs = Vec::new();
                for spec in c.get("conv_specs")?.as_array()? {
                    let arr = spec.as_array()?;
                    conv_specs.push((
                        arr[0].as_str()?.to_string(),
                        arr[1].as_usize()?,
                        arr[2].as_usize()?,
                    ));
                }
                Some(CnnMeta {
                    dir: dir.join(c.get("dir")?.as_str()?),
                    test_accuracy: c.get("test_accuracy")?.as_f64()?,
                    conv_specs,
                    img: c.get("img")?.as_usize()?,
                    num_classes: c.get("num_classes")?.as_usize()?,
                })
            }
            None => None,
        };
        Ok(ArtifactBundle {
            dir,
            lonum,
            by_name,
            cnn,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named '{name}'")))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(|s| s.as_str())
    }

    /// get-norm variant for an n×n matrix at tile size `lonum`.
    pub fn getnorm(&self, n: usize, lonum: usize, mxu: bool) -> Result<&ArtifactMeta> {
        let name = if mxu {
            format!("getnorm_mxu_n{n}_l{lonum}")
        } else {
            format!("getnorm_n{n}_l{lonum}")
        };
        self.get(&name)
    }

    /// Dense square GEMM baseline for n×n.
    pub fn dense(&self, n: usize, precision: &str) -> Result<&ArtifactMeta> {
        self.get(&format!("dense_n{n}_{precision}"))
    }

    /// Dense GEMM variant for an (m×k)·(k×n) product of any shape,
    /// resolved by the compiled input shapes — covers both the square
    /// `dense_n{N}` grid and the rectangular CNN-layer artifacts.
    pub fn dense_shaped(
        &self,
        m: usize,
        k: usize,
        n: usize,
        precision: &str,
    ) -> Result<&ArtifactMeta> {
        self.by_name
            .values()
            .find(|a| {
                a.kind == "dense"
                    && a.param("precision") == Some(precision)
                    && a.input_shapes == [vec![m, k], vec![k, n]]
            })
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no dense artifact for {m}x{k}x{n} {precision}"
                ))
            })
    }

    /// Smallest tile-GEMM batch variant at tile size `lonum` with capacity
    /// ≥ want (or the largest available if none fits; caller chunks).
    pub fn tilegemm(&self, want: usize, lonum: usize, precision: &str) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> = self
            .by_name
            .values()
            .filter(|a| {
                a.kind == "tilegemm"
                    && a.param("precision") == Some(precision)
                    && a.param_usize("lonum") == Some(lonum)
            })
            .collect();
        if candidates.is_empty() {
            return Err(Error::Artifact(format!(
                "no tilegemm artifacts for lonum {lonum} precision {precision}"
            )));
        }
        candidates.sort_by_key(|a| a.param_usize("batch").unwrap_or(0));
        for a in &candidates {
            if a.param_usize("batch").unwrap_or(0) >= want {
                return Ok(a);
            }
        }
        Ok(candidates.last().unwrap())
    }

    /// Sorted batch capacities of the tile-GEMM buckets for (lonum,
    /// precision) — used by the executor's greedy bucket packing.
    pub fn tilegemm_buckets(&self, lonum: usize, precision: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_name
            .values()
            .filter(|a| {
                a.kind == "tilegemm"
                    && a.param("precision") == Some(precision)
                    && a.param_usize("lonum") == Some(lonum)
            })
            .filter_map(|a| a.param_usize("batch"))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Smallest batched tile-axpby variant at tile size `lonum` with
    /// capacity ≥ want (largest available if none fits; caller chunks) —
    /// the expression graphs' device-side α·X + β·Y combine.
    pub fn axpby(&self, want: usize, lonum: usize) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> = self
            .by_name
            .values()
            .filter(|a| a.kind == "axpby" && a.param_usize("lonum") == Some(lonum))
            .collect();
        if candidates.is_empty() {
            return Err(Error::Artifact(format!(
                "no axpby artifacts for lonum {lonum}"
            )));
        }
        candidates.sort_by_key(|a| a.param_usize("batch").unwrap_or(0));
        for a in &candidates {
            if a.param_usize("batch").unwrap_or(0) >= want {
                return Ok(a);
            }
        }
        Ok(candidates.last().unwrap())
    }

    /// Sorted batch capacities of the axpby buckets for `lonum` (empty
    /// when the bundle carries none — callers fall back to the host-side
    /// combine).
    pub fn axpby_buckets(&self, lonum: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_name
            .values()
            .filter(|a| a.kind == "axpby" && a.param_usize("lonum") == Some(lonum))
            .filter_map(|a| a.param_usize("batch"))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Sparse-tile kernel with run width ≥ `run` at tile size `lonum`:
    /// smallest bucket that fits, like [`ArtifactBundle::tilegemm`]
    /// (callers split runs wider than the largest bucket).
    pub fn sptile(&self, run: usize, lonum: usize) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> = self
            .by_name
            .values()
            .filter(|a| a.kind == "sptile" && a.param_usize("lonum") == Some(lonum))
            .collect();
        if candidates.is_empty() {
            return Err(Error::Artifact(format!(
                "no sptile artifacts for lonum {lonum}"
            )));
        }
        candidates.sort_by_key(|a| a.param_usize("run").unwrap_or(0));
        for a in &candidates {
            if a.param_usize("run").unwrap_or(0) >= run {
                return Ok(a);
            }
        }
        Ok(*candidates.last().unwrap())
    }

    /// Sorted run widths of the sparse-tile buckets for `lonum` (empty
    /// when the bundle carries none — callers fall back to the host-side
    /// sparse kernel).
    pub fn sptile_runs(&self, lonum: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_name
            .values()
            .filter(|a| a.kind == "sptile" && a.param_usize("lonum") == Some(lonum))
            .filter_map(|a| a.param_usize("run"))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// τ-tuner for a BDIM×BDIM normmap.
    pub fn tune(&self, bdim: usize) -> Result<&ArtifactMeta> {
        self.get(&format!("tune_b{bdim}"))
    }

    /// Fused single-call SpAMM for n×n.
    pub fn spamm_fused(&self, n: usize, precision: &str) -> Result<&ArtifactMeta> {
        self.get(&format!("spamm_fused_n{n}_{precision}"))
    }

    /// All square sizes with a dense baseline (sorted) — bench grids.
    pub fn dense_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_name
            .values()
            .filter(|a| a.kind == "dense" && a.param("layer").is_none())
            .filter_map(|a| a.param_usize("n"))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_bundle(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        let manifest = r#"{
            "lonum": 32, "version": 1,
            "artifacts": [
                {"name": "getnorm_n256_l32", "kind": "getnorm",
                 "file": "x.hlo.txt", "n_outputs": 1,
                 "inputs": [{"shape": [256, 256], "dtype": "f32"}],
                 "params": {"n": 256, "lonum": 32, "precision": "f32"}},
                {"name": "tilegemm_l32_b64_f32", "kind": "tilegemm",
                 "file": "x.hlo.txt", "n_outputs": 1,
                 "inputs": [{"shape": [64, 32, 32], "dtype": "f32"},
                            {"shape": [64, 32, 32], "dtype": "f32"}],
                 "params": {"batch": 64, "lonum": 32, "precision": "f32"}},
                {"name": "tilegemm_l32_b256_f32", "kind": "tilegemm",
                 "file": "x.hlo.txt", "n_outputs": 1,
                 "inputs": [{"shape": [256, 32, 32], "dtype": "f32"},
                            {"shape": [256, 32, 32], "dtype": "f32"}],
                 "params": {"batch": 256, "lonum": 32, "precision": "f32"}}
            ]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn loads_and_resolves() {
        let dir = std::env::temp_dir().join("cuspamm_artifact_test");
        write_fake_bundle(&dir);
        let b = ArtifactBundle::load(&dir).unwrap();
        assert_eq!(b.lonum, 32);
        assert!(b.getnorm(256, 32, false).is_ok());
        assert!(b.getnorm(512, 32, false).is_err());
        // tilegemm selection: smallest batch that fits
        assert_eq!(
            b.tilegemm(10, 32, "f32").unwrap().param_usize("batch"),
            Some(64)
        );
        assert_eq!(
            b.tilegemm(100, 32, "f32").unwrap().param_usize("batch"),
            Some(256)
        );
        // over-capacity falls back to largest (caller chunks)
        assert_eq!(
            b.tilegemm(100_000, 32, "f32").unwrap().param_usize("batch"),
            Some(256)
        );
        assert!(b.tilegemm(1, 32, "bf16").is_err());
        assert!(b.tilegemm(1, 128, "f32").is_err());
        assert!(b.cnn.is_none());
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("cuspamm_artifact_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"lonum": 32, "artifacts": [{"name": "a", "kind": "dense",
                "file": "missing.hlo.txt", "n_outputs": 1, "inputs": []}]}"#,
        )
        .unwrap();
        assert!(ArtifactBundle::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = std::env::temp_dir().join("cuspamm_artifact_test3_nonexistent");
        let err = ArtifactBundle::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
