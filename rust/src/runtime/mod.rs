//! Runtime layer: loads the AOT-compiled HLO artifacts produced by
//! `make artifacts` and executes them on PJRT — the only place the crate
//! touches XLA.  One compiled executable per model variant, cached.
//!
//! `PjRtClient` in the `xla` crate is `Rc`-based (not `Send`), so each
//! simulated device ([`devicesim`]) owns its *own* client + executable
//! cache on its worker thread — which is also the honest model of one
//! context per physical GPU.

pub mod artifact;
pub mod client;
pub mod devicesim;
pub mod hostsim;
pub mod literal;
pub mod residency;

pub use artifact::{ArtifactBundle, ArtifactMeta};
pub use client::Runtime;
pub use devicesim::{BufferId, DevicePool, ExecInput, ExecRequest, HostTensor};
pub use residency::{ResidencyPool, ResidentOperand, TileHandle, TileKey};
