//! Multi-device simulation (DESIGN.md §2): the paper runs on up to 8
//! physical GPUs; here each simulated device is a worker thread owning its
//! own PJRT CPU client + executable cache (the `xla` crate's client is not
//! `Send`, and one-context-per-device is also the honest GPU model).
//!
//! Requests carry either plain host tensors (uploaded per call) or
//! [`BufferId`] handles to tensors staged on the device beforehand with
//! [`DevicePool::upload`] — the buffer-handle API that lets a caller pay
//! the host→device transfer once and reference the resident buffer in any
//! number of later executions.  Bounded channels provide the backpressure
//! that the paper's P-batched UM transfers provide on CUDA; per-device
//! busy and transfer clocks are kept separately.
//!
//! The SpAMM executor manages tile residency itself (see
//! [`crate::runtime::residency`], which keys on operand content and
//! packs batch buffers host-side); the staged-buffer API here is the
//! request-level counterpart for `DevicePool` users — currently
//! exercised by the integration suite, intended for SUMMA-style panel
//! broadcasts that re-reference whole staged operands.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::artifact::ArtifactBundle;
use crate::runtime::client::Runtime;
use crate::runtime::literal::{literal_f32, literal_to_vec};

/// A shape + flat f32 payload (what crosses thread boundaries).
pub type HostTensor = (Vec<usize>, Vec<f32>);

/// Handle to a tensor staged in one device's buffer store.  Carries the
/// issuing device so use on any other device is an error, never a silent
/// alias of that device's unrelated buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BufferId {
    dev: u32,
    id: u64,
}

/// One execution input: a host tensor to upload with the call, or a
/// handle to a buffer already resident on the device.
pub enum ExecInput {
    Host(HostTensor),
    Buffer(BufferId),
}

/// One execution request for a device worker.
pub struct ExecRequest {
    pub artifact: String,
    pub inputs: Vec<ExecInput>,
    pub reply: mpsc::Sender<Result<Vec<HostTensor>>>,
}

/// Everything a device worker can be asked to do.
enum Command {
    Exec(ExecRequest),
    /// Stage a tensor device-resident; replies with its handle.
    Upload {
        tensor: HostTensor,
        reply: mpsc::Sender<Result<BufferId>>,
    },
    /// Drop a staged buffer (missing ids are ignored).
    Free(BufferId),
}

struct Worker {
    sender: mpsc::SyncSender<Command>,
    handle: Option<JoinHandle<()>>,
    busy_nanos: Arc<AtomicU64>,
    transfer_nanos: Arc<AtomicU64>,
}

/// A pool of M simulated devices.
pub struct DevicePool {
    workers: Vec<Worker>,
}

impl DevicePool {
    /// Spawn `devices` workers; each compiles artifacts lazily from its own
    /// bundle view.  `queue_depth` bounds in-flight requests per device
    /// (backpressure).
    pub fn new(bundle: &ArtifactBundle, devices: usize, queue_depth: usize) -> Result<DevicePool> {
        let mut workers = Vec::with_capacity(devices);
        for dev in 0..devices {
            let (tx, rx) = mpsc::sync_channel::<Command>(queue_depth.max(1));
            let bundle = bundle.clone();
            let busy = Arc::new(AtomicU64::new(0));
            let busy_w = busy.clone();
            let transfer = Arc::new(AtomicU64::new(0));
            let transfer_w = transfer.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cuspamm-dev{dev}"))
                .spawn(move || {
                    let rt = match Runtime::new(&bundle) {
                        Ok(rt) => rt,
                        Err(e) => {
                            log::error!("device {dev}: client init failed: {e}");
                            // Drain, failing every request.
                            for cmd in rx {
                                let msg =
                                    format!("device {dev} failed to initialize");
                                match cmd {
                                    Command::Exec(req) => {
                                        let _ = req.reply.send(Err(Error::Coordinator(msg)));
                                    }
                                    Command::Upload { reply, .. } => {
                                        let _ = reply.send(Err(Error::Coordinator(msg)));
                                    }
                                    Command::Free(_) => {}
                                }
                            }
                            return;
                        }
                    };
                    // The device's staged-buffer store ("device memory").
                    let mut buffers: std::collections::BTreeMap<u64, xla::Literal> =
                        std::collections::BTreeMap::new();
                    let mut next_id = 0u64;
                    for cmd in rx {
                        match cmd {
                            Command::Exec(req) => {
                                let t = std::time::Instant::now();
                                let result = Self::run_one(&rt, dev, &req, &buffers);
                                busy_w.fetch_add(
                                    t.elapsed().as_nanos() as u64,
                                    Ordering::Relaxed,
                                );
                                // Receiver may have given up; ignore send failure.
                                let _ = req.reply.send(result);
                            }
                            Command::Upload { tensor, reply } => {
                                let t = std::time::Instant::now();
                                let result = literal_f32(&tensor.0, &tensor.1).map(|lit| {
                                    let id = next_id;
                                    next_id += 1;
                                    buffers.insert(id, lit);
                                    BufferId {
                                        dev: dev as u32,
                                        id,
                                    }
                                });
                                transfer_w.fetch_add(
                                    t.elapsed().as_nanos() as u64,
                                    Ordering::Relaxed,
                                );
                                let _ = reply.send(result);
                            }
                            Command::Free(buf) => {
                                buffers.remove(&buf.id);
                            }
                        }
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn device {dev}: {e}")))?;
            workers.push(Worker {
                sender: tx,
                handle: Some(handle),
                busy_nanos: busy,
                transfer_nanos: transfer,
            });
        }
        Ok(DevicePool { workers })
    }

    fn run_one(
        rt: &Runtime,
        dev: usize,
        req: &ExecRequest,
        buffers: &std::collections::BTreeMap<u64, xla::Literal>,
    ) -> Result<Vec<HostTensor>> {
        // Host inputs are uploaded with the call; buffer inputs execute
        // in place from the staging store.
        let mut uploaded = Vec::new();
        for input in &req.inputs {
            if let ExecInput::Host((dims, data)) = input {
                uploaded.push(Some(literal_f32(dims, data)?));
            } else {
                uploaded.push(None);
            }
        }
        let mut literals: Vec<&xla::Literal> = Vec::with_capacity(req.inputs.len());
        for (input, up) in req.inputs.iter().zip(&uploaded) {
            match input {
                ExecInput::Host(_) => literals.push(up.as_ref().unwrap()),
                ExecInput::Buffer(buf) => {
                    if buf.dev as usize != dev {
                        return Err(Error::Coordinator(format!(
                            "buffer {} belongs to device {}, not device {dev}",
                            buf.id, buf.dev
                        )));
                    }
                    literals.push(buffers.get(&buf.id).ok_or_else(|| {
                        Error::Coordinator(format!("unknown device buffer id {}", buf.id))
                    })?);
                }
            }
        }
        let outs = rt.execute_refs(&req.artifact, &literals)?;
        outs.iter().map(literal_to_vec).collect()
    }

    pub fn devices(&self) -> usize {
        self.workers.len()
    }

    fn send(&self, dev: usize, cmd: Command) -> Result<()> {
        self.workers[dev]
            .sender
            .send(cmd)
            .map_err(|_| Error::Coordinator(format!("device {dev} is gone")))
    }

    /// Stage a tensor on device `dev`; the returned handle stays valid
    /// until [`DevicePool::free`] (one transfer, any number of uses).
    pub fn upload(&self, dev: usize, tensor: HostTensor) -> Result<BufferId> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.send(
            dev,
            Command::Upload {
                tensor,
                reply: reply_tx,
            },
        )?;
        reply_rx
            .recv()
            .map_err(|_| Error::Coordinator(format!("device {dev} dropped reply")))?
    }

    /// Drop a staged buffer (unknown ids are a no-op).  The handle knows
    /// its device, so frees are always routed to the right store.
    pub fn free(&self, id: BufferId) -> Result<()> {
        let dev = id.dev as usize;
        if dev >= self.workers.len() {
            return Err(Error::Coordinator(format!(
                "buffer {} belongs to unknown device {dev}",
                id.id
            )));
        }
        self.send(dev, Command::Free(id))
    }

    /// Submit a request mixing host tensors and staged-buffer handles to
    /// device `dev`; blocks if its queue is full (backpressure, like a
    /// full CUDA stream).
    pub fn submit_inputs(
        &self,
        dev: usize,
        artifact: &str,
        inputs: Vec<ExecInput>,
    ) -> Result<mpsc::Receiver<Result<Vec<HostTensor>>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.send(
            dev,
            Command::Exec(ExecRequest {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            }),
        )?;
        Ok(reply_rx)
    }

    /// Submit host-tensor inputs (uploaded with the call).
    pub fn submit(
        &self,
        dev: usize,
        artifact: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<mpsc::Receiver<Result<Vec<HostTensor>>>> {
        self.submit_inputs(dev, artifact, inputs.into_iter().map(ExecInput::Host).collect())
    }

    /// Submit and wait (single round trip).
    pub fn call(
        &self,
        dev: usize,
        artifact: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        self.submit(dev, artifact, inputs)?
            .recv()
            .map_err(|_| Error::Coordinator(format!("device {dev} dropped reply")))?
    }

    /// Submit mixed inputs and wait.
    pub fn call_inputs(
        &self,
        dev: usize,
        artifact: &str,
        inputs: Vec<ExecInput>,
    ) -> Result<Vec<HostTensor>> {
        self.submit_inputs(dev, artifact, inputs)?
            .recv()
            .map_err(|_| Error::Coordinator(format!("device {dev} dropped reply")))?
    }

    /// Modeled device-busy seconds per device (the "GPU time" metric).
    pub fn busy_secs(&self) -> Vec<f64> {
        self.workers
            .iter()
            .map(|w| w.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9)
            .collect()
    }

    /// Seconds each device spent staging uploads (kept out of `busy`).
    pub fn transfer_secs(&self) -> Vec<f64> {
        self.workers
            .iter()
            .map(|w| w.transfer_nanos.load(Ordering::Relaxed) as f64 / 1e9)
            .collect()
    }

    pub fn reset_busy(&self) {
        for w in &self.workers {
            w.busy_nanos.store(0, Ordering::Relaxed);
            w.transfer_nanos.store(0, Ordering::Relaxed);
        }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                // Swap the real sender out and drop it so the worker's
                // `for cmd in rx` loop terminates, then join.
                let (dummy_tx, _dummy_rx) = mpsc::sync_channel::<Command>(1);
                drop(std::mem::replace(&mut w.sender, dummy_tx));
                let _ = h.join();
            }
        }
    }
}
