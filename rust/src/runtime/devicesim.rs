//! Multi-device simulation (DESIGN.md §2): the paper runs on up to 8
//! physical GPUs; here each simulated device is a worker thread owning its
//! own PJRT CPU client + executable cache (the `xla` crate's client is not
//! `Send`, and one-context-per-device is also the honest GPU model).
//!
//! Requests carry plain host tensors across the channel; the worker builds
//! literals, executes, and replies.  Bounded channels provide the
//! backpressure that the paper's P-batched UM transfers provide on CUDA.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::artifact::ArtifactBundle;
use crate::runtime::client::Runtime;
use crate::runtime::literal::{literal_f32, literal_to_vec};

/// A shape + flat f32 payload (what crosses thread boundaries).
pub type HostTensor = (Vec<usize>, Vec<f32>);

/// One execution request for a device worker.
pub struct ExecRequest {
    pub artifact: String,
    pub inputs: Vec<HostTensor>,
    pub reply: mpsc::Sender<Result<Vec<HostTensor>>>,
}

struct Worker {
    sender: mpsc::SyncSender<ExecRequest>,
    handle: Option<JoinHandle<()>>,
    busy_nanos: Arc<AtomicU64>,
}

/// A pool of M simulated devices.
pub struct DevicePool {
    workers: Vec<Worker>,
}

impl DevicePool {
    /// Spawn `devices` workers; each compiles artifacts lazily from its own
    /// bundle view.  `queue_depth` bounds in-flight requests per device
    /// (backpressure).
    pub fn new(bundle: &ArtifactBundle, devices: usize, queue_depth: usize) -> Result<DevicePool> {
        let mut workers = Vec::with_capacity(devices);
        for dev in 0..devices {
            let (tx, rx) = mpsc::sync_channel::<ExecRequest>(queue_depth.max(1));
            let bundle = bundle.clone();
            let busy = Arc::new(AtomicU64::new(0));
            let busy_w = busy.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cuspamm-dev{dev}"))
                .spawn(move || {
                    let rt = match Runtime::new(&bundle) {
                        Ok(rt) => rt,
                        Err(e) => {
                            log::error!("device {dev}: client init failed: {e}");
                            // Drain, failing every request.
                            for req in rx {
                                let _ = req
                                    .reply
                                    .send(Err(Error::Coordinator(format!(
                                        "device {dev} failed to initialize"
                                    ))));
                            }
                            return;
                        }
                    };
                    for req in rx {
                        let t = std::time::Instant::now();
                        let result = Self::run_one(&rt, &req);
                        busy_w.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        // Receiver may have given up; ignore send failure.
                        let _ = req.reply.send(result);
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn device {dev}: {e}")))?;
            workers.push(Worker {
                sender: tx,
                handle: Some(handle),
                busy_nanos: busy,
            });
        }
        Ok(DevicePool { workers })
    }

    fn run_one(rt: &Runtime, req: &ExecRequest) -> Result<Vec<HostTensor>> {
        let mut literals = Vec::with_capacity(req.inputs.len());
        for (dims, data) in &req.inputs {
            literals.push(literal_f32(dims, data)?);
        }
        let outs = rt.execute(&req.artifact, &literals)?;
        outs.iter().map(literal_to_vec).collect()
    }

    pub fn devices(&self) -> usize {
        self.workers.len()
    }

    /// Submit a request to device `dev`; blocks if its queue is full
    /// (backpressure, like a full CUDA stream).
    pub fn submit(
        &self,
        dev: usize,
        artifact: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<mpsc::Receiver<Result<Vec<HostTensor>>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.workers[dev]
            .sender
            .send(ExecRequest {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| Error::Coordinator(format!("device {dev} is gone")))?;
        Ok(reply_rx)
    }

    /// Submit and wait (single round trip).
    pub fn call(
        &self,
        dev: usize,
        artifact: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        self.submit(dev, artifact, inputs)?
            .recv()
            .map_err(|_| Error::Coordinator(format!("device {dev} dropped reply")))?
    }

    /// Modeled device-busy seconds per device (the "GPU time" metric).
    pub fn busy_secs(&self) -> Vec<f64> {
        self.workers
            .iter()
            .map(|w| w.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9)
            .collect()
    }

    pub fn reset_busy(&self) {
        for w in &self.workers {
            w.busy_nanos.store(0, Ordering::Relaxed);
        }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                // Swap the real sender out and drop it so the worker's
                // `for req in rx` loop terminates, then join.
                let (dummy_tx, _dummy_rx) = mpsc::sync_channel::<ExecRequest>(1);
                drop(std::mem::replace(&mut w.sender, dummy_tx));
                let _ = h.join();
            }
        }
    }
}
