//! Matrix/tensor ↔ `xla::Literal` conversion helpers.

use crate::error::{Error, Result};
use crate::matrix::Matrix;

/// Build an f32 literal of the given shape from a flat slice
/// (`create_from_shape_and_untyped_data` consumes raw bytes; one
/// native-endian byte copy here keeps the crate `forbid(unsafe_code)`).
pub fn literal_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let count: usize = dims.iter().product::<usize>().max(1);
    if data.len() != count && !(dims.is_empty() && data.len() == 1) {
        return Err(Error::Shape(format!(
            "literal shape {dims:?} needs {count} values, got {}",
            data.len()
        )));
    }
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_ne_bytes());
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        &bytes,
    )?)
}

/// Scalar f32 literal (shape `f32[]`).
pub fn literal_scalar(x: f32) -> Result<xla::Literal> {
    literal_f32(&[], &[x])
}

/// Matrix → 2-D literal.
pub fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    literal_f32(&[m.rows(), m.cols()], m.data())
}

/// Literal → flat f32 vec + dims.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<(Vec<usize>, Vec<f32>)> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok((dims, data))
}

/// Literal → Matrix (must be rank 2).
pub fn literal_to_matrix(lit: &xla::Literal) -> Result<Matrix> {
    let (dims, data) = literal_to_vec(lit)?;
    if dims.len() != 2 {
        return Err(Error::Shape(format!("expected rank-2 literal, got {dims:?}")));
    }
    Matrix::from_vec(dims[0], dims[1], data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::randn(3, 5, 1);
        let lit = matrix_to_literal(&m).unwrap();
        let back = literal_to_matrix(&lit).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn scalar_literal() {
        let lit = literal_scalar(3.25).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![3.25]);
    }

    #[test]
    fn rank3_roundtrip() {
        let data: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let lit = literal_f32(&[2, 3, 4], &data).unwrap();
        let (dims, back) = literal_to_vec(&lit).unwrap();
        assert_eq!(dims, vec![2, 3, 4]);
        assert_eq!(back, data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[2, 2], &[1.0; 3]).is_err());
    }
}
