//! Hostsim artifact bundles: synthesize a manifest + self-describing op
//! files that the offline PJRT simulator (vendored `xla` crate) can
//! "compile" and execute on the host.
//!
//! The real Layer-1/2 pipeline (`make artifacts`) needs a python/JAX
//! toolchain to AOT-lower Pallas kernels to HLO.  In environments without
//! it, [`write_bundle`] produces a bundle with the same manifest schema
//! and artifact naming grid (`dense_n{N}_{prec}`, `tilegemm_l{L}_b{B}_…`,
//! `getnorm…`, `tune_b{B}`, `spamm_fused…`) whose files carry a hostsim
//! op spec instead of HLO text, with the same numeric contract.  The
//! integration tests and benches use [`test_bundle`] when no real
//! artifact directory is present, so the whole request path stays
//! exercised end-to-end.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::runtime::artifact::ArtifactBundle;

/// What to put in a synthesized bundle.
#[derive(Clone, Debug)]
pub struct HostsimSpec {
    /// Tile edge (LoNum) of the whole grid.
    pub lonum: usize,
    /// Square sizes with dense baselines (per precision).
    pub dense_sizes: Vec<usize>,
    /// Rectangular (m, k, n) dense baselines (per precision) — the
    /// CNN-shaped im2col GEMMs, so conv layers resolve a device artifact
    /// instead of falling back to host GEMM when no real bundle exists.
    pub dense_rect: Vec<(usize, usize, usize)>,
    /// Square sizes with get-norm artifacts (host + MXU variants).
    pub getnorm_sizes: Vec<usize>,
    /// Tile-GEMM batch buckets (per precision).
    pub tilegemm_batches: Vec<usize>,
    /// Batched tile-axpby buckets (f32; the expression graphs' device-side
    /// α·X + β·Y combine).
    pub axpby_batches: Vec<usize>,
    /// Normmap BDIMs with an on-device τ tuner.
    pub tune_bdims: Vec<usize>,
    /// Square sizes with a fused single-call SpAMM (f32 only).
    pub fused_sizes: Vec<usize>,
    /// Precision variants for dense/tile-GEMM ("f32", "bf16").
    pub precisions: Vec<&'static str>,
}

impl Default for HostsimSpec {
    fn default() -> Self {
        HostsimSpec {
            lonum: 32,
            dense_sizes: vec![256, 512],
            // im2col shapes of small conv layers: (C_out, C_in·9, N·H·W).
            dense_rect: vec![(64, 288, 256), (128, 576, 64)],
            getnorm_sizes: vec![256, 512],
            tilegemm_batches: vec![16, 64, 256],
            axpby_batches: vec![16, 64, 256],
            tune_bdims: vec![8, 16],
            fused_sizes: vec![256],
            precisions: vec!["f32", "bf16"],
        }
    }
}

struct ManifestBuilder {
    dir: PathBuf,
    entries: Vec<String>,
}

impl ManifestBuilder {
    fn artifact(
        &mut self,
        name: &str,
        kind: &str,
        inputs: &[&[usize]],
        n_outputs: usize,
        params: &[(&str, String)],
        body: &str,
    ) -> Result<()> {
        let file = format!("{name}.hostsim.txt");
        std::fs::write(self.dir.join(&file), body)?;
        let mut inputs_json = String::new();
        for (i, dims) in inputs.iter().enumerate() {
            if i > 0 {
                inputs_json.push(',');
            }
            let dims_json: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
            let _ = write!(
                inputs_json,
                r#"{{"shape": [{}], "dtype": "f32"}}"#,
                dims_json.join(",")
            );
        }
        let mut params_json = String::new();
        for (i, (k, v)) in params.iter().enumerate() {
            if i > 0 {
                params_json.push(',');
            }
            let quoted = if v.parse::<f64>().is_ok() {
                v.clone()
            } else {
                format!("\"{v}\"")
            };
            let _ = write!(params_json, "\"{k}\": {quoted}");
        }
        self.entries.push(format!(
            r#"{{"name": "{name}", "kind": "{kind}", "file": "{file}", "n_outputs": {n_outputs}, "inputs": [{inputs_json}], "params": {{{params_json}}}}}"#
        ));
        Ok(())
    }
}

/// Write a hostsim bundle (manifest + op files) under `dir`.
pub fn write_bundle(dir: impl AsRef<Path>, spec: &HostsimSpec) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let l = spec.lonum;
    let mut mb = ManifestBuilder {
        dir: dir.to_path_buf(),
        entries: Vec::new(),
    };

    for &prec in &spec.precisions {
        for &n in &spec.dense_sizes {
            mb.artifact(
                &format!("dense_n{n}_{prec}"),
                "dense",
                &[&[n, n], &[n, n]],
                1,
                &[
                    ("n", n.to_string()),
                    ("precision", prec.to_string()),
                ],
                &format!(
                    "hostsim v1\nkind = dense\nm = {n}\nk = {n}\nn = {n}\nprecision = {prec}\n"
                ),
            )?;
        }
        for &(m, k, n) in &spec.dense_rect {
            // Same naming scheme as the python AOT grid's CNN GEMMs
            // (`dense_{layer}_{m}x{k}x{n}_{prec}`); the `layer` param
            // keeps them out of the square-size bench grids.
            mb.artifact(
                &format!("dense_sim_{m}x{k}x{n}_{prec}"),
                "dense",
                &[&[m, k], &[k, n]],
                1,
                &[
                    ("m", m.to_string()),
                    ("k", k.to_string()),
                    ("n", n.to_string()),
                    ("precision", prec.to_string()),
                    ("layer", "sim".to_string()),
                ],
                &format!(
                    "hostsim v1\nkind = dense\nm = {m}\nk = {k}\nn = {n}\nprecision = {prec}\n"
                ),
            )?;
        }
        for &b in &spec.tilegemm_batches {
            mb.artifact(
                &format!("tilegemm_l{l}_b{b}_{prec}"),
                "tilegemm",
                &[&[b, l, l], &[b, l, l]],
                1,
                &[
                    ("batch", b.to_string()),
                    ("lonum", l.to_string()),
                    ("precision", prec.to_string()),
                ],
                &format!(
                    "hostsim v1\nkind = tilegemm\nbatch = {b}\nlonum = {l}\nprecision = {prec}\n"
                ),
            )?;
        }
    }
    for &b in &spec.axpby_batches {
        // Element-wise linear combination is precision-agnostic here:
        // one f32 variant per bucket (bf16 rounding happens, as on real
        // hardware, in the GEMM operands — not in the accumulate/combine).
        mb.artifact(
            &format!("axpby_l{l}_b{b}_f32"),
            "axpby",
            &[&[b, l, l], &[b, l, l], &[], &[]],
            1,
            &[
                ("batch", b.to_string()),
                ("lonum", l.to_string()),
                ("precision", "f32".to_string()),
            ],
            &format!("hostsim v1\nkind = axpby\nbatch = {b}\nlonum = {l}\n"),
        )?;
    }
    for &n in &spec.getnorm_sizes {
        mb.artifact(
            &format!("getnorm_n{n}_l{l}"),
            "getnorm",
            &[&[n, n]],
            1,
            &[("n", n.to_string()), ("lonum", l.to_string())],
            &format!("hostsim v1\nkind = getnorm\nn = {n}\nlonum = {l}\n"),
        )?;
        mb.artifact(
            &format!("getnorm_mxu_n{n}_l{l}"),
            "getnorm",
            &[&[n, n]],
            1,
            &[("n", n.to_string()), ("lonum", l.to_string())],
            &format!("hostsim v1\nkind = getnorm\nn = {n}\nlonum = {l}\nmxu = true\n"),
        )?;
    }
    for &b in &spec.tune_bdims {
        mb.artifact(
            &format!("tune_b{b}"),
            "tune",
            &[&[b, b], &[b, b], &[]],
            2,
            &[("bdim", b.to_string())],
            &format!("hostsim v1\nkind = tune\nbdim = {b}\n"),
        )?;
    }
    for &n in &spec.fused_sizes {
        mb.artifact(
            &format!("spamm_fused_n{n}_f32"),
            "spamm_fused",
            &[&[n, n], &[n, n], &[]],
            1,
            &[
                ("n", n.to_string()),
                ("lonum", l.to_string()),
                ("precision", "f32".to_string()),
            ],
            &format!("hostsim v1\nkind = spamm_fused\nn = {n}\nlonum = {l}\nprecision = f32\n"),
        )?;
    }

    let manifest = format!(
        r#"{{"lonum": {l}, "version": 1, "artifacts": [{}]}}"#,
        mb.entries.join(",")
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;
    Ok(())
}

/// Locate a real AOT artifact bundle — `CUSPAMM_ARTIFACTS`, then
/// `artifacts/`, then `../artifacts/` — falling back to the synthesized
/// hostsim bundle when none exists.  The single bundle-discovery path
/// for tests and benches.
pub fn find_or_test_bundle() -> Result<ArtifactBundle> {
    let candidates = [
        std::env::var("CUSPAMM_ARTIFACTS").unwrap_or_default(),
        "artifacts".to_string(),
        "../artifacts".to_string(),
    ];
    for c in candidates.iter().filter(|c| !c.is_empty()) {
        if Path::new(c).join("manifest.json").exists() {
            return ArtifactBundle::load(c);
        }
    }
    test_bundle()
}

/// Load (writing on first use in this process) the default hostsim bundle
/// for tests and benches that have no real artifact directory.  A failed
/// synthesis is remembered as the failure it was — every caller gets the
/// root cause, not a confusing partial-bundle load error.
pub fn test_bundle() -> Result<ArtifactBundle> {
    static DIR: std::sync::OnceLock<std::result::Result<PathBuf, String>> =
        std::sync::OnceLock::new();
    let outcome = DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("cuspamm_hostsim_{}", std::process::id()));
        write_bundle(&dir, &HostsimSpec::default())
            .map(|_| dir)
            .map_err(|e| e.to_string())
    });
    match outcome {
        Ok(dir) => ArtifactBundle::load(dir),
        Err(e) => Err(crate::error::Error::Artifact(format!(
            "hostsim bundle synthesis failed: {e}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Matrix;
    use crate::runtime::Runtime;

    #[test]
    fn bundle_loads_and_resolves_grid() {
        let b = test_bundle().unwrap();
        assert_eq!(b.lonum, 32);
        assert!(b.dense(256, "f32").is_ok());
        assert!(b.dense(256, "bf16").is_ok());
        assert!(b.getnorm(256, 32, false).is_ok());
        assert!(b.getnorm(256, 32, true).is_ok());
        assert!(b.tune(16).is_ok());
        assert!(b.spamm_fused(256, "f32").is_ok());
        assert_eq!(b.tilegemm_buckets(32, "f32"), vec![16, 64, 256]);
        assert_eq!(b.axpby_buckets(32), vec![16, 64, 256]);
        assert!(b.axpby(10, 32).is_ok());
        assert!(b.axpby(10, 64).is_err());
        assert_eq!(b.dense_sizes(), vec![256, 512]);
    }

    #[test]
    fn dense_artifact_executes_on_simulator() {
        let b = test_bundle().unwrap();
        let rt = Runtime::new(&b).unwrap();
        let a = Matrix::randn(256, 256, 1);
        let c = rt.dense(&a, &Matrix::eye(256), "f32").unwrap();
        assert!(a.error_fnorm(&c).unwrap() < 1e-6);
    }

    #[test]
    fn rectangular_dense_resolves_and_executes() {
        let b = test_bundle().unwrap();
        // The rect grid resolves by compiled input shape, not by name.
        assert!(b.dense_shaped(64, 288, 256, "f32").is_ok());
        assert!(b.dense_shaped(64, 288, 256, "bf16").is_ok());
        assert!(b.dense_shaped(64, 288, 999, "f32").is_err());
        // Rect artifacts carry a `layer` param and must stay out of the
        // square-size bench grid.
        assert_eq!(b.dense_sizes(), vec![256, 512]);

        let rt = Runtime::new(&b).unwrap();
        let a = Matrix::randn(64, 288, 2);
        let x = Matrix::randn(288, 256, 3);
        let c = rt.dense(&a, &x, "f32").unwrap();
        let want = a.matmul(&x).unwrap();
        assert!(c.error_fnorm(&want).unwrap() / want.fnorm().max(1e-30) < 1e-5);
    }
}
