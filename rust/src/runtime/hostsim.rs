//! Hostsim artifact bundles: synthesize a manifest + self-describing op
//! files that the offline PJRT simulator (vendored `xla` crate) can
//! "compile" and execute on the host.
//!
//! The real Layer-1/2 pipeline (`make artifacts`) needs a python/JAX
//! toolchain to AOT-lower Pallas kernels to HLO.  In environments without
//! it, [`write_bundle`] produces a bundle with the same manifest schema
//! and artifact naming grid (`dense_n{N}_{prec}`, `tilegemm_l{L}_b{B}_…`,
//! `getnorm…`, `tune_b{B}`, `spamm_fused…`) whose files carry a hostsim
//! op spec instead of HLO text, with the same numeric contract.  The
//! integration tests and benches use [`test_bundle`] when no real
//! artifact directory is present, so the whole request path stays
//! exercised end-to-end.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::runtime::artifact::ArtifactBundle;

/// What to put in a synthesized bundle.
#[derive(Clone, Debug)]
pub struct HostsimSpec {
    /// Tile edge (LoNum) of the whole grid.
    pub lonum: usize,
    /// Square sizes with dense baselines (per precision).
    pub dense_sizes: Vec<usize>,
    /// Rectangular (m, k, n) dense baselines (per precision) — the
    /// CNN-shaped im2col GEMMs, so conv layers resolve a device artifact
    /// instead of falling back to host GEMM when no real bundle exists.
    pub dense_rect: Vec<(usize, usize, usize)>,
    /// Square sizes with get-norm artifacts (host + MXU variants).
    pub getnorm_sizes: Vec<usize>,
    /// Tile-GEMM batch buckets (per precision).
    pub tilegemm_batches: Vec<usize>,
    /// Batched tile-axpby buckets (f32; the expression graphs' device-side
    /// α·X + β·Y combine).
    pub axpby_batches: Vec<usize>,
    /// Sparse-tile run widths (f32): `sptile_l{L}_r{R}` executes one
    /// C[l,l] += A[l,R·l]·B[R·l,l] product over COO-packed operands —
    /// R = 1 is the single sparse product, R > 1 the packed fused run.
    pub sptile_runs: Vec<usize>,
    /// Normmap BDIMs with an on-device τ tuner.
    pub tune_bdims: Vec<usize>,
    /// Square sizes with a fused single-call SpAMM (f32 only).
    pub fused_sizes: Vec<usize>,
    /// Precision variants for dense/tile-GEMM ("f32", "bf16").
    pub precisions: Vec<&'static str>,
    /// Synthesize-and-freeze the CNN fixture (weights + frozen test set
    /// + recorded accuracy) so the Table 5 paths run without the
    /// python/JAX training toolchain.
    pub cnn: bool,
}

impl Default for HostsimSpec {
    fn default() -> Self {
        HostsimSpec {
            lonum: 32,
            dense_sizes: vec![256, 512],
            // im2col shapes of small conv layers: (C_out, C_in·9, N·H·W).
            dense_rect: vec![(64, 288, 256), (128, 576, 64)],
            getnorm_sizes: vec![256, 512],
            tilegemm_batches: vec![16, 64, 256],
            axpby_batches: vec![16, 64, 256],
            sptile_runs: vec![1, 2, 4],
            tune_bdims: vec![8, 16],
            fused_sizes: vec![256],
            precisions: vec!["f32", "bf16"],
            cnn: true,
        }
    }
}

struct ManifestBuilder {
    dir: PathBuf,
    entries: Vec<String>,
}

impl ManifestBuilder {
    fn artifact(
        &mut self,
        name: &str,
        kind: &str,
        inputs: &[&[usize]],
        n_outputs: usize,
        params: &[(&str, String)],
        body: &str,
    ) -> Result<()> {
        let file = format!("{name}.hostsim.txt");
        std::fs::write(self.dir.join(&file), body)?;
        let mut inputs_json = String::new();
        for (i, dims) in inputs.iter().enumerate() {
            if i > 0 {
                inputs_json.push(',');
            }
            let dims_json: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
            let _ = write!(
                inputs_json,
                r#"{{"shape": [{}], "dtype": "f32"}}"#,
                dims_json.join(",")
            );
        }
        let mut params_json = String::new();
        for (i, (k, v)) in params.iter().enumerate() {
            if i > 0 {
                params_json.push(',');
            }
            let quoted = if v.parse::<f64>().is_ok() {
                v.clone()
            } else {
                format!("\"{v}\"")
            };
            let _ = write!(params_json, "\"{k}\": {quoted}");
        }
        self.entries.push(format!(
            r#"{{"name": "{name}", "kind": "{kind}", "file": "{file}", "n_outputs": {n_outputs}, "inputs": [{inputs_json}], "params": {{{params_json}}}}}"#
        ));
        Ok(())
    }
}

/// Write a hostsim bundle (manifest + op files) under `dir`.
pub fn write_bundle(dir: impl AsRef<Path>, spec: &HostsimSpec) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let l = spec.lonum;
    let mut mb = ManifestBuilder {
        dir: dir.to_path_buf(),
        entries: Vec::new(),
    };

    for &prec in &spec.precisions {
        for &n in &spec.dense_sizes {
            mb.artifact(
                &format!("dense_n{n}_{prec}"),
                "dense",
                &[&[n, n], &[n, n]],
                1,
                &[
                    ("n", n.to_string()),
                    ("precision", prec.to_string()),
                ],
                &format!(
                    "hostsim v1\nkind = dense\nm = {n}\nk = {n}\nn = {n}\nprecision = {prec}\n"
                ),
            )?;
        }
        for &(m, k, n) in &spec.dense_rect {
            // Same naming scheme as the python AOT grid's CNN GEMMs
            // (`dense_{layer}_{m}x{k}x{n}_{prec}`); the `layer` param
            // keeps them out of the square-size bench grids.
            mb.artifact(
                &format!("dense_sim_{m}x{k}x{n}_{prec}"),
                "dense",
                &[&[m, k], &[k, n]],
                1,
                &[
                    ("m", m.to_string()),
                    ("k", k.to_string()),
                    ("n", n.to_string()),
                    ("precision", prec.to_string()),
                    ("layer", "sim".to_string()),
                ],
                &format!(
                    "hostsim v1\nkind = dense\nm = {m}\nk = {k}\nn = {n}\nprecision = {prec}\n"
                ),
            )?;
        }
        for &b in &spec.tilegemm_batches {
            mb.artifact(
                &format!("tilegemm_l{l}_b{b}_{prec}"),
                "tilegemm",
                &[&[b, l, l], &[b, l, l]],
                1,
                &[
                    ("batch", b.to_string()),
                    ("lonum", l.to_string()),
                    ("precision", prec.to_string()),
                ],
                &format!(
                    "hostsim v1\nkind = tilegemm\nbatch = {b}\nlonum = {l}\nprecision = {prec}\n"
                ),
            )?;
        }
    }
    for &b in &spec.axpby_batches {
        // Element-wise linear combination is precision-agnostic here:
        // one f32 variant per bucket (bf16 rounding happens, as on real
        // hardware, in the GEMM operands — not in the accumulate/combine).
        mb.artifact(
            &format!("axpby_l{l}_b{b}_f32"),
            "axpby",
            &[&[b, l, l], &[b, l, l], &[], &[]],
            1,
            &[
                ("batch", b.to_string()),
                ("lonum", l.to_string()),
                ("precision", "f32".to_string()),
            ],
            &format!("hostsim v1\nkind = axpby\nbatch = {b}\nlonum = {l}\n"),
        )?;
    }
    for &n in &spec.getnorm_sizes {
        mb.artifact(
            &format!("getnorm_n{n}_l{l}"),
            "getnorm",
            &[&[n, n]],
            1,
            &[("n", n.to_string()), ("lonum", l.to_string())],
            &format!("hostsim v1\nkind = getnorm\nn = {n}\nlonum = {l}\n"),
        )?;
        mb.artifact(
            &format!("getnorm_mxu_n{n}_l{l}"),
            "getnorm",
            &[&[n, n]],
            1,
            &[("n", n.to_string()), ("lonum", l.to_string())],
            &format!("hostsim v1\nkind = getnorm\nn = {n}\nlonum = {l}\nmxu = true\n"),
        )?;
    }
    for &r in &spec.sptile_runs {
        // COO-packed sparse tile product: padded value/index arrays of
        // capacity r·l² (the dense nnz bound of an l×(r·l) block) plus a
        // 2-entry (a_nnz, b_nnz) meta array.
        let cap = r * l * l;
        mb.artifact(
            &format!("sptile_l{l}_r{r}_f32"),
            "sptile",
            &[&[cap], &[cap], &[cap], &[cap], &[2]],
            1,
            &[
                ("lonum", l.to_string()),
                ("run", r.to_string()),
                ("cap", cap.to_string()),
                ("precision", "f32".to_string()),
            ],
            &format!("hostsim v1\nkind = sptile\nlonum = {l}\nrun = {r}\ncap = {cap}\n"),
        )?;
    }
    for &b in &spec.tune_bdims {
        mb.artifact(
            &format!("tune_b{b}"),
            "tune",
            &[&[b, b], &[b, b], &[]],
            2,
            &[("bdim", b.to_string())],
            &format!("hostsim v1\nkind = tune\nbdim = {b}\n"),
        )?;
    }
    for &n in &spec.fused_sizes {
        mb.artifact(
            &format!("spamm_fused_n{n}_f32"),
            "spamm_fused",
            &[&[n, n], &[n, n], &[]],
            1,
            &[
                ("n", n.to_string()),
                ("lonum", l.to_string()),
                ("precision", "f32".to_string()),
            ],
            &format!("hostsim v1\nkind = spamm_fused\nn = {n}\nlonum = {l}\nprecision = f32\n"),
        )?;
    }

    // Frozen CNN fixture: deterministic weights + a frozen test set whose
    // labels are the network's own host-forward predictions (recorded
    // accuracy is exact by construction) — the Table 5 paths stop
    // skipping when the python/JAX training toolchain is absent.
    let cnn_json = if spec.cnn {
        let (acc, conv_specs, img, classes) = write_cnn_fixture(dir)?;
        let specs_json = conv_specs
            .iter()
            .map(|(n, ci, co)| format!(r#"["{n}", {ci}, {co}]"#))
            .collect::<Vec<_>>()
            .join(",");
        format!(
            r#", "cnn": {{"dir": "cnn", "test_accuracy": {acc:.6}, "conv_specs": [{specs_json}], "img": {img}, "num_classes": {classes}}}"#
        )
    } else {
        String::new()
    };
    let manifest = format!(
        r#"{{"lonum": {l}, "version": 1, "artifacts": [{}]{cnn_json}}}"#,
        mb.entries.join(",")
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;
    Ok(())
}

/// Synthesize-and-freeze the CNN fixture under `<dir>/cnn/`: a small
/// 3-conv network (the §4.3.2 architecture scaled down) with seeded
/// weights, and a frozen test set labeled by the network's *own*
/// host-forward argmax — so the recorded accuracy is exactly 1.0 and
/// every later evaluation of the same frozen set reproduces it.
/// Candidates whose top-2 logit margin is under 1e-2 are dropped, so
/// τ = 0 SpAMM substitutions (numerically ≈1e-5 off host GEMM) cannot
/// flip a prediction.  Returns (accuracy, conv_specs, img, classes).
#[allow(clippy::type_complexity)]
fn write_cnn_fixture(
    dir: &Path,
) -> Result<(f64, Vec<(String, usize, usize)>, usize, usize)> {
    use crate::cnn::Cnn;
    use crate::matrix::tensorio::{save_tensor_f32, save_tensor_i32};
    use crate::matrix::Matrix;
    use crate::runtime::artifact::CnnMeta;

    const IMG: usize = 8;
    const CLASSES: usize = 4;
    const CANDIDATES: usize = 200;
    const KEEP: usize = 64;
    let conv_specs: Vec<(String, usize, usize)> = vec![
        ("conv1".to_string(), 1, 4),
        ("conv2".to_string(), 4, 8),
        ("conv3".to_string(), 8, 8),
    ];
    let cnn_dir = dir.join("cnn");
    std::fs::create_dir_all(&cnn_dir)?;

    // Seeded weights scaled so activations stay O(1) through ReLU.
    let scales = [0.5f32, 0.3, 0.2];
    for (li, (name, cin, cout)) in conv_specs.iter().enumerate() {
        let w = Matrix::randn(*cout, cin * 9, 9000 + li as u64);
        let wd: Vec<f32> = w.data().iter().map(|v| v * scales[li]).collect();
        save_tensor_f32(&cnn_dir.join(format!("{name}_w.cstn")), &[*cout, cin * 9], &wd)?;
        let b = Matrix::randn(1, *cout, 9100 + li as u64);
        let bd: Vec<f32> = b.data().iter().map(|v| v * 0.1).collect();
        save_tensor_f32(&cnn_dir.join(format!("{name}_b.cstn")), &[*cout], &bd)?;
    }
    // After two 2×2 maxpools an 8×8 image is 2×2; conv3 has 8 channels.
    let feat = 8 * (IMG / 4) * (IMG / 4);
    let fw = Matrix::randn(feat, CLASSES, 9200);
    let fwd: Vec<f32> = fw.data().iter().map(|v| v * 0.3).collect();
    save_tensor_f32(&cnn_dir.join("fc_w.cstn"), &[feat, CLASSES], &fwd)?;
    let fb = Matrix::randn(1, CLASSES, 9300);
    let fbd: Vec<f32> = fb.data().iter().map(|v| v * 0.1).collect();
    save_tensor_f32(&cnn_dir.join("fc_b.cstn"), &[CLASSES], &fbd)?;

    // Candidate images; labels provisional until the margin filter runs.
    let cand = Matrix::randn(CANDIDATES, IMG * IMG, 9400);
    save_tensor_f32(
        &cnn_dir.join("test_images.cstn"),
        &[CANDIDATES, 1, IMG, IMG],
        cand.data(),
    )?;
    save_tensor_i32(
        &cnn_dir.join("test_labels.cstn"),
        &[CANDIDATES],
        &[0i32; CANDIDATES],
    )?;
    let provisional = CnnMeta {
        dir: cnn_dir.clone(),
        test_accuracy: 0.0,
        conv_specs: conv_specs.clone(),
        img: IMG,
        num_classes: CLASSES,
    };
    let model = Cnn::load(&provisional)?;
    let (images, _) = model.test_batch(0, CANDIDATES);
    let logits = model.forward(&images, &std::collections::BTreeMap::new(), None)?;

    let mut keep_idx: Vec<usize> = Vec::new();
    let mut labels: Vec<i32> = Vec::new();
    for r in 0..CANDIDATES {
        let row = logits.row(r);
        let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
        let mut second_v = f32::NEG_INFINITY;
        for (c, &v) in row.iter().enumerate() {
            if v > best_v {
                second_v = best_v;
                best_v = v;
                best = c;
            } else if v > second_v {
                second_v = v;
            }
        }
        if best_v - second_v > 1e-2 {
            keep_idx.push(r);
            labels.push(best as i32);
            if keep_idx.len() == KEEP {
                break;
            }
        }
    }
    if keep_idx.is_empty() {
        // Pathological margins (should not happen with these seeds):
        // freeze the first candidate unfiltered so the fixture exists.
        let row = logits.row(0);
        let best = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        keep_idx.push(0);
        labels.push(best as i32);
    }

    // Freeze the selected set over the provisional files.
    let per = IMG * IMG;
    let mut frozen = Vec::with_capacity(keep_idx.len() * per);
    for &r in &keep_idx {
        frozen.extend_from_slice(&cand.data()[r * per..(r + 1) * per]);
    }
    save_tensor_f32(
        &cnn_dir.join("test_images.cstn"),
        &[keep_idx.len(), 1, IMG, IMG],
        &frozen,
    )?;
    save_tensor_i32(&cnn_dir.join("test_labels.cstn"), &[keep_idx.len()], &labels)?;
    // Labels are the model's own predictions on the frozen set, so the
    // recorded accuracy is exact.
    Ok((1.0, conv_specs, IMG, CLASSES))
}

/// Locate a real AOT artifact bundle — `CUSPAMM_ARTIFACTS`, then
/// `artifacts/`, then `../artifacts/` — falling back to the synthesized
/// hostsim bundle when none exists.  The single bundle-discovery path
/// for tests and benches.
pub fn find_or_test_bundle() -> Result<ArtifactBundle> {
    let candidates = [
        std::env::var("CUSPAMM_ARTIFACTS").unwrap_or_default(),
        "artifacts".to_string(),
        "../artifacts".to_string(),
    ];
    for c in candidates.iter().filter(|c| !c.is_empty()) {
        if Path::new(c).join("manifest.json").exists() {
            return ArtifactBundle::load(c);
        }
    }
    test_bundle()
}

/// Synthesize-or-restore a hostsim bundle through a warm-start store.
///
/// The store entry is keyed on the full synthesis spec (every field —
/// sizes, buckets, precisions, CNN fixture), so a restored bundle is the
/// one this spec would have produced: synthesis is deterministic in the
/// spec, and the store's directory digest catches any on-disk drift.  A
/// hit loads the persisted directory without re-running synthesis (the
/// CNN fixture training is the expensive part); a miss synthesizes once
/// to a scratch directory and persists it.  Returns the loaded bundle
/// and whether it came from the store.
pub fn warm_bundle(
    store: &crate::store::WarmStore,
    spec: &HostsimSpec,
) -> Result<(ArtifactBundle, bool)> {
    let name = spec_key(spec);
    if let Some(dir) = store.load_bundle_dir(&name) {
        match ArtifactBundle::load(&dir) {
            Ok(b) => return Ok((b, true)),
            // Digest matched but the manifest no longer parses (schema
            // skew from an older writer): self-heal and resynthesize.
            Err(_) => store.evict_bundle(&name),
        }
    }
    let scratch = std::env::temp_dir().join(format!(
        "cuspamm_hostsim_stage_{}_{}",
        std::process::id(),
        name
    ));
    let _ = std::fs::remove_dir_all(&scratch);
    write_bundle(&scratch, spec)?;
    let dir = store
        .save_bundle_dir(&name, &scratch)
        .unwrap_or_else(|| scratch.clone());
    let bundle = ArtifactBundle::load(&dir)?;
    if dir != scratch {
        let _ = std::fs::remove_dir_all(&scratch);
    }
    Ok((bundle, false))
}

/// Deterministic store key of a synthesis spec: two specs share a stored
/// bundle iff every field matches.
fn spec_key(spec: &HostsimSpec) -> String {
    let repr = format!("{spec:?}");
    format!("hostsim-{}", crate::store::checksum_hex(repr.as_bytes()))
}

/// Load (writing on first use in this process) the default hostsim bundle
/// for tests and benches that have no real artifact directory.  A failed
/// synthesis is remembered as the failure it was — every caller gets the
/// root cause, not a confusing partial-bundle load error.
pub fn test_bundle() -> Result<ArtifactBundle> {
    static DIR: std::sync::OnceLock<std::result::Result<PathBuf, String>> =
        std::sync::OnceLock::new();
    let outcome = DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("cuspamm_hostsim_{}", std::process::id()));
        write_bundle(&dir, &HostsimSpec::default())
            .map(|_| dir)
            .map_err(|e| e.to_string())
    });
    match outcome {
        Ok(dir) => ArtifactBundle::load(dir),
        Err(e) => Err(crate::error::Error::Artifact(format!(
            "hostsim bundle synthesis failed: {e}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Matrix;
    use crate::runtime::Runtime;

    #[test]
    fn bundle_loads_and_resolves_grid() {
        let b = test_bundle().unwrap();
        assert_eq!(b.lonum, 32);
        assert!(b.dense(256, "f32").is_ok());
        assert!(b.dense(256, "bf16").is_ok());
        assert!(b.getnorm(256, 32, false).is_ok());
        assert!(b.getnorm(256, 32, true).is_ok());
        assert!(b.tune(16).is_ok());
        assert!(b.spamm_fused(256, "f32").is_ok());
        assert_eq!(b.tilegemm_buckets(32, "f32"), vec![16, 64, 256]);
        assert_eq!(b.axpby_buckets(32), vec![16, 64, 256]);
        assert_eq!(b.sptile_runs(32), vec![1, 2, 4]);
        assert_eq!(b.sptile(1, 32).unwrap().param_usize("run"), Some(1));
        assert_eq!(b.sptile(3, 32).unwrap().param_usize("run"), Some(4));
        // Over-wide runs fall back to the largest bucket (caller splits).
        assert_eq!(b.sptile(9, 32).unwrap().param_usize("run"), Some(4));
        assert!(b.sptile(1, 64).is_err());
        assert!(b.axpby(10, 32).is_ok());
        assert!(b.axpby(10, 64).is_err());
        assert_eq!(b.dense_sizes(), vec![256, 512]);
    }

    #[test]
    fn cnn_fixture_is_frozen_and_self_consistent() {
        let b = test_bundle().unwrap();
        let meta = b.cnn.clone().expect("hostsim bundle carries the CNN fixture");
        assert_eq!(meta.img, 8);
        assert_eq!(meta.num_classes, 4);
        assert_eq!(meta.conv_specs.len(), 3);
        let cnn = crate::cnn::Cnn::load(&meta).unwrap();
        assert!(!cnn.test_labels.is_empty());
        // The frozen labels are the model's own host-forward argmax:
        // accuracy reproduces the recorded value exactly.
        let acc = cnn
            .accuracy(&std::collections::BTreeMap::new(), None, 32, None)
            .unwrap();
        assert_eq!(acc, meta.test_accuracy, "frozen fixture accuracy drifted");
        // Deterministic: a second synthesis freezes identical labels.
        let dir2 = std::env::temp_dir().join(format!(
            "cuspamm_hostsim_cnn2_{}",
            std::process::id()
        ));
        write_bundle(&dir2, &HostsimSpec::default()).unwrap();
        let b2 = ArtifactBundle::load(&dir2).unwrap();
        let cnn2 = crate::cnn::Cnn::load(&b2.cnn.clone().unwrap()).unwrap();
        assert_eq!(cnn.test_labels, cnn2.test_labels);
        assert_eq!(cnn.test_images.data, cnn2.test_images.data);
    }

    #[test]
    fn dense_artifact_executes_on_simulator() {
        let b = test_bundle().unwrap();
        let rt = Runtime::new(&b).unwrap();
        let a = Matrix::randn(256, 256, 1);
        let c = rt.dense(&a, &Matrix::eye(256), "f32").unwrap();
        assert!(a.error_fnorm(&c).unwrap() < 1e-6);
    }

    #[test]
    fn rectangular_dense_resolves_and_executes() {
        let b = test_bundle().unwrap();
        // The rect grid resolves by compiled input shape, not by name.
        assert!(b.dense_shaped(64, 288, 256, "f32").is_ok());
        assert!(b.dense_shaped(64, 288, 256, "bf16").is_ok());
        assert!(b.dense_shaped(64, 288, 999, "f32").is_err());
        // Rect artifacts carry a `layer` param and must stay out of the
        // square-size bench grid.
        assert_eq!(b.dense_sizes(), vec![256, 512]);

        let rt = Runtime::new(&b).unwrap();
        let a = Matrix::randn(64, 288, 2);
        let x = Matrix::randn(288, 256, 3);
        let c = rt.dense(&a, &x, "f32").unwrap();
        let want = a.matmul(&x).unwrap();
        assert!(c.error_fnorm(&want).unwrap() / want.fnorm().max(1e-30) < 1e-5);
    }
}
