//! Device-resident operand-tile pool — the §3.3 A-block reuse, made
//! explicit.
//!
//! The paper's blocking strategy keeps A-blocks on the GPU across the many
//! B-tiles (and across power/purification iterations) that reuse them.
//! Here every padded-operand tile uploaded to a device lands in that
//! device's [`ResidencyPool`], keyed on the operand's 128-bit content
//! fingerprint plus the tile coordinate.  The executor's gather stage asks
//! the pool for *handles* instead of copying tile data:
//!
//! * **hit** — the tile is already device-resident; no host→device
//!   transfer happens, only a refcount bump.
//! * **miss** — the tile is uploaded once (one `LoNum²·4`-byte copy) and
//!   becomes resident for every later product, chunk, batch, and multiply
//!   that references the same operand content.
//!
//! The pool is bounded by a byte budget (`device_mem_budget`); inserts
//! evict least-recently-used tiles first.  A tile is *pinned* while any
//! [`TileHandle`] to it is alive (the gather/exec pipeline holds handles
//! for in-flight chunks) and pinned tiles are never evicted — if every
//! resident tile is pinned the pool overflows its budget instead, exactly
//! like a real allocator that cannot free memory the kernels are reading.
//!
//! One pool per device: the engine owns one, the coordinator owns one per
//! device worker.  The pool is `Sync` (a worker's transfer thread acquires
//! handles while the exec thread reads them), but never shared *across*
//! devices — device memory is not.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::spamm::cache::Fingerprint;
use crate::spamm::normmap::{tile_density, tile_fnorm, NormMap};
use crate::telemetry;

/// One device-resident tile: the "device memory" copy of a LoNum² block.
#[derive(Debug)]
pub struct DeviceTile {
    pub data: Vec<f32>,
}

/// Refcounted handle to a resident tile.  Holding it pins the tile
/// (eviction skips pinned entries); dropping it unpins.
pub type TileHandle = Arc<DeviceTile>;

/// On-device payload layout of a resident tile.  A tile's packed form is
/// packed at floor 0.0 ([`crate::sparse::pack_tile`]), so both layouts are
/// pure functions of the operand content and the key stays
/// content-addressed — the same tile may be resident in both formats at
/// once (e.g. one consumer runs dense, another sparse) without colliding.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TileFormat {
    /// Full row-major LoNum² buffer.
    Dense,
    /// COO entry list `[nnz, idx, val, …]` (variable length).
    Packed,
}

/// Pool key: which operand content + which tile of it + payload format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileKey {
    /// Content fingerprint of the padded operand matrix.
    pub op: Fingerprint,
    /// (tile row, tile col) within the operand's tile grid.
    pub tile: (u32, u32),
    /// Payload layout resident under this key.
    pub fmt: TileFormat,
}

impl TileKey {
    pub fn new(op: Fingerprint, tile: (usize, usize)) -> TileKey {
        TileKey {
            op,
            tile: (tile.0 as u32, tile.1 as u32),
            fmt: TileFormat::Dense,
        }
    }

    /// Key for the COO-packed payload of the same tile content.
    pub fn packed(op: Fingerprint, tile: (usize, usize)) -> TileKey {
        TileKey {
            op,
            tile: (tile.0 as u32, tile.1 as u32),
            fmt: TileFormat::Packed,
        }
    }
}

/// Outcome of one [`ResidencyPool::acquire`] call.
pub struct Acquired {
    pub handle: TileHandle,
    /// Whether the tile was already resident (no upload happened).
    pub hit: bool,
    /// Tiles evicted to make room for this insert (0 on hits).
    pub evicted: usize,
}

/// Outcome of one [`ResidencyPool::patch_operand`] call (a delta
/// update's per-pool migration).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PatchOutcome {
    /// Changed dense tiles replaced by a fresh upload.
    pub uploaded_tiles: usize,
    /// Bytes of those uploads (also counted in `PoolStats::uploaded_bytes`).
    pub uploaded_bytes: u64,
    /// Unchanged tiles re-keyed to the new fingerprint with no transfer.
    pub rekeyed_tiles: usize,
    /// Stale packed payloads of changed tiles dropped.
    pub dropped_stale: usize,
}

impl PatchOutcome {
    /// Fold another pool's outcome in — the coordinator patches one pool
    /// per device and reports the aggregate.
    pub fn absorb(&mut self, o: &PatchOutcome) {
        self.uploaded_tiles += o.uploaded_tiles;
        self.uploaded_bytes += o.uploaded_bytes;
        self.rekeyed_tiles += o.rekeyed_tiles;
        self.dropped_stale += o.dropped_stale;
    }
}

/// Monotonic counters snapshot of a pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Bytes uploaded host→device (misses · tile bytes).
    pub uploaded_bytes: u64,
    /// Bytes *not* transferred thanks to residency hits.
    pub saved_bytes: u64,
    /// Currently resident bytes (may exceed the budget only while every
    /// tile is pinned).
    pub resident_bytes: u64,
    pub resident_tiles: u64,
}

/// One resident tile in a [`PoolSnapshot`] — the audit-facing view of a
/// map entry.
#[derive(Clone, Debug)]
pub struct PoolAuditTile {
    pub op: Fingerprint,
    pub tile: (usize, usize),
    pub fmt: TileFormat,
    /// f32 element count of the resident payload (LoNum² for dense,
    /// variable for packed COO).
    pub payload_len: usize,
    /// Whether a handle to this tile is currently held outside the pool.
    pub in_flight: bool,
}

/// Point-in-time pool state for the static auditor
/// ([`ResidencyPool::audit_snapshot`]).
#[derive(Clone, Debug)]
pub struct PoolSnapshot {
    pub tiles: Vec<PoolAuditTile>,
    /// Resident bytes as the pool accounts them (the auditor recomputes
    /// the sum independently from `tiles`).
    pub bytes: usize,
    /// Pinned operand fingerprints with their pin counts.
    pub pinned: Vec<(Fingerprint, u32)>,
}

/// A resident tile plus the sequence number of its latest use.
struct Slot {
    handle: TileHandle,
    seq: u64,
}

/// One recency record.  The queue uses lazy deletion: a record is *live*
/// only while its `seq` matches the slot's current `seq`; stale records
/// are discarded when they surface at the front.  This keeps every touch
/// O(1) (push + counter bump) instead of an O(n) scan — the default byte
/// budget admits tens of thousands of resident tiles, and touches are the
/// warm gather stage's hot path.
struct Recency {
    key: TileKey,
    seq: u64,
}

struct Inner {
    map: HashMap<TileKey, Slot>,
    /// Front ≈ least recently used (modulo stale records).
    queue: VecDeque<Recency>,
    next_seq: u64,
    bytes: usize,
    stats: PoolStats,
    /// Operand fingerprints pinned by the session's operand store (value =
    /// pin count: one per prepared plan referencing the operand).  Every
    /// tile of a pinned operand is exempt from eviction, whether it is
    /// resident already or uploaded later.
    pinned_ops: HashMap<Fingerprint, u32>,
}

impl Inner {
    fn op_pinned(&self, fp: &Fingerprint) -> bool {
        self.pinned_ops.contains_key(fp)
    }
}

impl Inner {
    /// Mark `key` most-recently-used (O(1) amortized).
    fn touch(&mut self, key: TileKey) {
        self.next_seq += 1;
        let seq = self.next_seq;
        if let Some(slot) = self.map.get_mut(&key) {
            slot.seq = seq;
        }
        self.queue.push_back(Recency { key, seq });
        self.compact_if_bloated();
    }

    /// Drop stale recency records once the queue outgrows the live set —
    /// keeps the lazy-deletion queue amortized O(1) per touch.
    fn compact_if_bloated(&mut self) {
        if self.queue.len() > 2 * self.map.len() + 8 {
            let map = &self.map;
            self.queue
                .retain(|r| map.get(&r.key).is_some_and(|s| s.seq == r.seq));
        }
    }
}

/// Per-device operand-tile pool (see module docs).
pub struct ResidencyPool {
    inner: Mutex<Inner>,
    /// Byte budget; `usize::MAX` means unlimited.
    budget: usize,
}

impl ResidencyPool {
    /// `budget_bytes == 0` means unlimited.
    pub fn new(budget_bytes: usize) -> ResidencyPool {
        ResidencyPool {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                queue: VecDeque::new(),
                next_seq: 0,
                bytes: 0,
                stats: PoolStats::default(),
                pinned_ops: HashMap::new(),
            }),
            budget: if budget_bytes == 0 {
                usize::MAX
            } else {
                budget_bytes
            },
        }
    }

    /// The configured byte budget (`usize::MAX` = unlimited).
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Fetch the resident tile for `key`, uploading via `fill` on a miss.
    /// `tile_elems` is the f32 element count of one tile (LoNum²).
    pub fn acquire(
        &self,
        key: TileKey,
        tile_elems: usize,
        fill: impl FnOnce(&mut [f32]),
    ) -> Acquired {
        let bytes = tile_elems * std::mem::size_of::<f32>();
        let mut inner = self.inner.lock().unwrap();
        if let Some(handle) = inner.map.get(&key).map(|s| s.handle.clone()) {
            inner.touch(key);
            inner.stats.hits += 1;
            inner.stats.saved_bytes += bytes as u64;
            telemetry::global().add("spamm.residency.hits", 1);
            return Acquired {
                handle,
                hit: true,
                evicted: 0,
            };
        }
        // Miss: upload (the one host→device copy this tile will ever see
        // while resident), then insert under the byte budget.
        let mut data = vec![0.0f32; tile_elems];
        fill(&mut data);
        let handle: TileHandle = Arc::new(DeviceTile { data });
        let evicted = evict_for(&mut inner, self.budget, bytes);
        inner.map.insert(
            key,
            Slot {
                handle: handle.clone(),
                seq: 0,
            },
        );
        inner.touch(key);
        inner.bytes += bytes;
        inner.stats.misses += 1;
        inner.stats.uploaded_bytes += bytes as u64;
        inner.stats.resident_bytes = inner.bytes as u64;
        inner.stats.resident_tiles = inner.map.len() as u64;
        telemetry::global().add("spamm.residency.misses", 1);
        telemetry::global().add("spamm.transfer.uploaded_bytes", bytes as u64);
        Acquired {
            handle,
            hit: false,
            evicted,
        }
    }

    /// Variable-length sibling of [`ResidencyPool::acquire`] for payloads
    /// whose size is data-dependent (COO-packed tiles): `build` produces
    /// the full payload on a miss, and byte accounting — uploads, savings,
    /// residency — follows the *actual* payload length, so compressed
    /// staging is visible as fewer uploaded bytes than the dense path.
    pub fn acquire_with(&self, key: TileKey, build: impl FnOnce() -> Vec<f32>) -> Acquired {
        let mut inner = self.inner.lock().unwrap();
        if let Some(handle) = inner.map.get(&key).map(|s| s.handle.clone()) {
            let bytes = handle.data.len() * std::mem::size_of::<f32>();
            inner.touch(key);
            inner.stats.hits += 1;
            inner.stats.saved_bytes += bytes as u64;
            telemetry::global().add("spamm.residency.hits", 1);
            return Acquired {
                handle,
                hit: true,
                evicted: 0,
            };
        }
        let data = build();
        let bytes = data.len() * std::mem::size_of::<f32>();
        let handle: TileHandle = Arc::new(DeviceTile { data });
        let evicted = evict_for(&mut inner, self.budget, bytes);
        inner.map.insert(
            key,
            Slot {
                handle: handle.clone(),
                seq: 0,
            },
        );
        inner.touch(key);
        inner.bytes += bytes;
        inner.stats.misses += 1;
        inner.stats.uploaded_bytes += bytes as u64;
        inner.stats.resident_bytes = inner.bytes as u64;
        inner.stats.resident_tiles = inner.map.len() as u64;
        telemetry::global().add("spamm.residency.misses", 1);
        telemetry::global().add("spamm.transfer.uploaded_bytes", bytes as u64);
        Acquired {
            handle,
            hit: false,
            evicted,
        }
    }

    /// Register a *device-produced* tile (a scatter-accumulated expression
    /// intermediate): the data was computed on this device, so no
    /// host→device transfer happened and the miss/upload counters stay
    /// untouched — only resident bytes (and, under budget pressure,
    /// evictions of other tiles) move.  An existing entry under the same
    /// key is replaced.  Returns the handle, which pins the tile while
    /// held.
    pub fn insert(&self, key: TileKey, data: Vec<f32>) -> TileHandle {
        let bytes = data.len() * std::mem::size_of::<f32>();
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= old.handle.data.len() * std::mem::size_of::<f32>();
        }
        evict_for(&mut inner, self.budget, bytes);
        let handle: TileHandle = Arc::new(DeviceTile { data });
        inner.map.insert(
            key,
            Slot {
                handle: handle.clone(),
                seq: 0,
            },
        );
        inner.touch(key);
        inner.bytes += bytes;
        inner.stats.resident_bytes = inner.bytes as u64;
        inner.stats.resident_tiles = inner.map.len() as u64;
        handle
    }

    /// Migrate operand `old_fp`'s resident tiles to `new_fp` after a
    /// delta update, uploading only the changed tiles:
    ///
    /// * **unchanged tiles** are re-keyed in place — dense *and* packed
    ///   payloads (a packed payload is a pure function of unchanged
    ///   content, so it stays valid) — with no transfer and no hit/miss
    ///   accounting; only their recency refreshes.
    /// * **changed dense tiles** are replaced by a fresh upload via
    ///   `fill` (counted as a miss + `uploaded_bytes`, exactly like an
    ///   `acquire` miss — it *is* a host→device copy).
    /// * **changed packed tiles** are dropped: the compressed payload
    ///   describes the old content and would poison a sparse dispatch;
    ///   the next sparse consumer re-packs from the new content.
    /// * the operand's **pin count** (plans referencing it) migrates
    ///   wholesale to the new fingerprint.
    ///
    /// Changed tiles that are not resident are skipped (`fill` never
    /// runs for them) — the next gather uploads them on demand from the
    /// updated operand.  Net pool bytes are unchanged modulo dropped
    /// packed payloads, so no eviction pass is needed.
    pub fn patch_operand(
        &self,
        old_fp: Fingerprint,
        new_fp: Fingerprint,
        changed: &[(usize, usize)],
        tile_elems: usize,
        mut fill: impl FnMut((usize, usize), &mut [f32]),
    ) -> PatchOutcome {
        let mut out = PatchOutcome::default();
        let changed_set: std::collections::HashSet<(u32, u32)> =
            changed.iter().map(|&(i, j)| (i as u32, j as u32)).collect();
        let mut inner = self.inner.lock().unwrap();
        let old_keys: Vec<TileKey> = inner
            .map
            .keys()
            .filter(|k| k.op == old_fp)
            .copied()
            .collect();
        for key in old_keys {
            let Some(slot) = inner.map.remove(&key) else {
                continue;
            };
            let len_bytes = slot.handle.data.len() * std::mem::size_of::<f32>();
            let nk = TileKey { op: new_fp, ..key };
            if changed_set.contains(&key.tile) {
                inner.bytes -= len_bytes;
                match key.fmt {
                    TileFormat::Dense => {
                        let mut data = vec![0.0f32; tile_elems];
                        fill((key.tile.0 as usize, key.tile.1 as usize), &mut data);
                        let bytes = tile_elems * std::mem::size_of::<f32>();
                        if let Some(prev) = inner.map.remove(&nk) {
                            inner.bytes -=
                                prev.handle.data.len() * std::mem::size_of::<f32>();
                        }
                        let handle: TileHandle = Arc::new(DeviceTile { data });
                        inner.map.insert(nk, Slot { handle, seq: 0 });
                        inner.touch(nk);
                        inner.bytes += bytes;
                        inner.stats.misses += 1;
                        inner.stats.uploaded_bytes += bytes as u64;
                        out.uploaded_tiles += 1;
                        out.uploaded_bytes += bytes as u64;
                        telemetry::global().add("spamm.residency.misses", 1);
                        telemetry::global()
                            .add("spamm.transfer.uploaded_bytes", bytes as u64);
                    }
                    TileFormat::Packed => {
                        out.dropped_stale += 1;
                    }
                }
            } else {
                if let Some(prev) = inner.map.remove(&nk) {
                    inner.bytes -= prev.handle.data.len() * std::mem::size_of::<f32>();
                }
                inner.map.insert(nk, slot);
                inner.touch(nk);
                out.rekeyed_tiles += 1;
            }
        }
        if let Some(n) = inner.pinned_ops.remove(&old_fp) {
            *inner.pinned_ops.entry(new_fp).or_insert(0) += n;
        }
        inner.stats.resident_bytes = inner.bytes as u64;
        inner.stats.resident_tiles = inner.map.len() as u64;
        out
    }

    /// Drop every currently-unpinned tile of operand `fp` — the
    /// expression executor's retirement path: when an intermediate's last
    /// consumer finishes, its tiles are freed immediately instead of
    /// lingering as LRU prey.  Tiles with live handles or a store pin
    /// survive.  Returns the freed tile count.
    pub fn remove_operand(&self, fp: Fingerprint) -> usize {
        let mut inner = self.inner.lock().unwrap();
        if inner.op_pinned(&fp) {
            return 0;
        }
        let victims: Vec<TileKey> = inner
            .map
            .iter()
            .filter(|(k, s)| k.op == fp && Arc::strong_count(&s.handle) == 1)
            .map(|(k, _)| *k)
            .collect();
        for k in &victims {
            if let Some(s) = inner.map.remove(k) {
                inner.bytes -= s.handle.data.len() * std::mem::size_of::<f32>();
            }
        }
        // Stale recency records are lazily discarded by eviction/compact.
        inner.stats.resident_bytes = inner.bytes as u64;
        inner.stats.resident_tiles = inner.map.len() as u64;
        victims.len()
    }

    /// Pin every tile of operand `fp` — resident now or uploaded later —
    /// against eviction.  Store-driven: the session's operand store pins
    /// the operands of every prepared plan so request churn cannot evict
    /// a plan's working set.  Pins are counted (one per plan); returns the
    /// operand's currently-resident tile count.
    pub fn pin_operand(&self, fp: Fingerprint) -> usize {
        let mut inner = self.inner.lock().unwrap();
        *inner.pinned_ops.entry(fp).or_insert(0) += 1;
        inner.map.keys().filter(|k| k.op == fp).count()
    }

    /// Drop one pin of operand `fp` (tiles become evictable again once the
    /// last pin is released).  Returns whether the operand is still pinned.
    pub fn unpin_operand(&self, fp: Fingerprint) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if let Some(n) = inner.pinned_ops.get_mut(&fp) {
            *n -= 1;
            if *n == 0 {
                inner.pinned_ops.remove(&fp);
                return false;
            }
            return true;
        }
        false
    }

    /// Number of distinct pinned operand fingerprints.
    pub fn pinned_operands(&self) -> usize {
        self.inner.lock().unwrap().pinned_ops.len()
    }

    /// Consistent point-in-time view of the pool's internal state for
    /// the static auditor ([`crate::audit::audit_pool`]): every resident
    /// tile with its payload length, the byte counter as accounted (not
    /// recomputed), and the pinned-operand table.  One lock, no LRU
    /// touches — auditing must not perturb eviction order.
    pub fn audit_snapshot(&self) -> PoolSnapshot {
        let inner = self.inner.lock().unwrap();
        PoolSnapshot {
            tiles: inner
                .map
                .iter()
                .map(|(k, s)| PoolAuditTile {
                    op: k.op,
                    tile: (k.tile.0 as usize, k.tile.1 as usize),
                    fmt: k.fmt,
                    payload_len: s.handle.data.len(),
                    in_flight: Arc::strong_count(&s.handle) > 1,
                })
                .collect(),
            bytes: inner.bytes,
            pinned: inner.pinned_ops.iter().map(|(f, n)| (*f, *n)).collect(),
        }
    }

    /// Deliberately corrupt the byte counter — mutation-test hook for
    /// the auditor's accounting check; unreachable outside unit tests.
    #[cfg(test)]
    pub(crate) fn corrupt_bytes_for_test(&self, bytes: usize) {
        self.inner.lock().unwrap().bytes = bytes;
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        let mut s = inner.stats;
        s.resident_bytes = inner.bytes as u64;
        s.resident_tiles = inner.map.len() as u64;
        s
    }

    pub fn resident_tiles(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Bytes of operand `fp`'s tiles among `tiles` that are resident
    /// right now — the cheap placement probe the residency-aware
    /// partitioner scores candidate owners with.  One lock, no touches:
    /// probing residency must not perturb the LRU order.
    pub fn resident_bytes_of(&self, fp: Fingerprint, tiles: &[(usize, usize)]) -> usize {
        let inner = self.inner.lock().unwrap();
        tiles
            .iter()
            .filter_map(|&t| inner.map.get(&TileKey::new(fp, t)))
            .map(|s| s.handle.data.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// Tile coordinates of operand `fp` resident right now (one lock, no
    /// LRU touches) — the bulk snapshot behind
    /// [`ResidencyPool::resident_bytes_of`] for full-grid placement.
    pub fn resident_tiles_of(&self, fp: Fingerprint) -> Vec<(usize, usize)> {
        let inner = self.inner.lock().unwrap();
        inner
            .map
            .keys()
            .filter(|k| k.op == fp && k.fmt == TileFormat::Dense)
            .map(|k| (k.tile.0 as usize, k.tile.1 as usize))
            .collect()
    }

    /// Drop every unpinned tile — operator surface for long-running
    /// services that want to release device memory between unrelated
    /// workloads without waiting for LRU churn.  Pinned tiles survive:
    /// their handles are still in flight, or their operand is pinned by
    /// the store.
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        let keep: Vec<TileKey> = inner
            .map
            .iter()
            .filter(|(k, s)| {
                Arc::strong_count(&s.handle) > 1 || inner.op_pinned(&k.op)
            })
            .map(|(k, _)| *k)
            .collect();
        let mut bytes = 0usize;
        let mut map = HashMap::new();
        for k in &keep {
            if let Some(s) = inner.map.remove(k) {
                bytes += s.handle.data.len() * std::mem::size_of::<f32>();
                map.insert(*k, s);
            }
        }
        inner.map = map;
        inner.queue.clear();
        inner.bytes = bytes;
        for k in keep {
            inner.touch(k);
        }
    }
}

/// A matrix that lives entirely on one device: the output of an
/// expression-graph node, held as refcounted tile handles under a
/// *derived* content fingerprint, never materialized on the host.
///
/// Holding the operand pins every tile (handles keep the refcount above
/// one, and pinned tiles are never evicted), so a consumer's gather
/// stage is guaranteed pool hits — zero transfer bytes.  The exact
/// tile-norm map is computed at construction from the freshly
/// accumulated tiles (the device-side get-norm): bitwise identical to
/// the host `normmap` of the same content, with no host round-trip.
pub struct ResidentOperand {
    fp: Fingerprint,
    lonum: usize,
    logical_rows: usize,
    logical_cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    /// Row-major (tile_rows × tile_cols) handles.
    tiles: Vec<TileHandle>,
    /// Exact tile Frobenius norms (device-side get-norm at scatter time).
    normmap: Arc<Matrix>,
    /// Exact per-tile density census (same floor and count-then-scale
    /// arithmetic as the host census), taken from the same freshly
    /// accumulated tiles — lets consumers route sparse/packed off a
    /// resident intermediate instead of assuming dense.
    density: Arc<Matrix>,
}

impl ResidentOperand {
    /// Build from scatter-accumulated output tiles (the executor's
    /// `TileAccumulator::into_tiles` order: sorted row-major, complete
    /// grid).  Each tile is registered in `pool` under `fp` (when a pool
    /// exists) so consuming nodes gather with zero transfer; without a
    /// pool the handles themselves are the storage.
    #[allow(clippy::too_many_arguments)]
    pub fn from_tiles(
        fp: Fingerprint,
        lonum: usize,
        logical_rows: usize,
        logical_cols: usize,
        tile_rows: usize,
        tile_cols: usize,
        tiles: Vec<((usize, usize), Vec<f32>)>,
        pool: Option<&ResidencyPool>,
    ) -> Result<ResidentOperand> {
        if tiles.len() != tile_rows * tile_cols {
            return Err(Error::Coordinator(format!(
                "resident operand: {} tiles for a {}x{} grid",
                tiles.len(),
                tile_rows,
                tile_cols
            )));
        }
        let mut normmap = Matrix::zeros(tile_rows, tile_cols);
        let mut density = Matrix::zeros(tile_rows, tile_cols);
        let mut handles = Vec::with_capacity(tiles.len());
        for (idx, ((ti, tj), data)) in tiles.into_iter().enumerate() {
            if (ti * tile_cols + tj) != idx || data.len() != lonum * lonum {
                return Err(Error::Coordinator(format!(
                    "resident operand: tile ({ti},{tj}) out of order or mis-sized"
                )));
            }
            normmap[(ti, tj)] = tile_fnorm(&data);
            density[(ti, tj)] = tile_density(&data);
            let handle = match pool {
                Some(p) => p.insert(TileKey::new(fp, (ti, tj)), data),
                None => Arc::new(DeviceTile { data }),
            };
            handles.push(handle);
        }
        Ok(ResidentOperand {
            fp,
            lonum,
            logical_rows,
            logical_cols,
            tile_rows,
            tile_cols,
            tiles: handles,
            normmap: Arc::new(normmap),
            density: Arc::new(density),
        })
    }

    pub fn fingerprint(&self) -> Fingerprint {
        self.fp
    }

    pub fn lonum(&self) -> usize {
        self.lonum
    }

    pub fn logical_rows(&self) -> usize {
        self.logical_rows
    }

    pub fn logical_cols(&self) -> usize {
        self.logical_cols
    }

    pub fn tile_rows(&self) -> usize {
        self.tile_rows
    }

    pub fn tile_cols(&self) -> usize {
        self.tile_cols
    }

    /// Exact tile-norm map (computed device-side at construction).
    pub fn normmap(&self) -> &Arc<Matrix> {
        &self.normmap
    }

    /// Exact per-tile density census (computed device-side at
    /// construction, same rule as the host census).
    pub fn densitymap(&self) -> &Arc<Matrix> {
        &self.density
    }

    /// Norm *and* density map of this resident value — both channels
    /// exact and bitwise identical to the host maps of the same content,
    /// so a consumer's adaptive schedule routes a chained intermediate
    /// exactly like the loop path that round-trips through the host.
    pub fn norm_density_map(&self) -> NormMap {
        NormMap {
            norms: (*self.normmap).clone(),
            density: (*self.density).clone(),
        }
    }

    /// Resident bytes held by this operand's tiles.
    pub fn resident_bytes(&self) -> usize {
        self.tiles.len() * self.lonum * self.lonum * std::mem::size_of::<f32>()
    }

    /// Copy tile (ti, tj) into `dst` (row-major lonum²) — the gather
    /// stage's fill for this source (device-side copy, no host data).
    pub fn copy_tile(&self, ti: usize, tj: usize, dst: &mut [f32]) {
        let data = &self.tiles[ti * self.tile_cols + tj].data;
        dst[..data.len()].copy_from_slice(data);
    }

    /// One row segment of tile row `ti`, in-tile row `r`, tile column
    /// `tj` — the building block of padded-row-major traversals.
    pub fn row_segment(&self, ti: usize, r: usize, tj: usize) -> &[f32] {
        &self.tiles[ti * self.tile_cols + tj].data[r * self.lonum..(r + 1) * self.lonum]
    }

    /// ‖·‖_F over the logical matrix, summed in padded row-major order.
    /// Padding is exactly zero (products of zero-padded operands, axpby
    /// of zero padding), and adding 0.0 to a non-negative f64 is exact —
    /// so this equals `Matrix::fnorm` of the downloaded matrix bitwise.
    pub fn fnorm(&self) -> f64 {
        let l = self.lonum;
        let mut acc = 0.0f64;
        for ti in 0..self.tile_rows {
            for r in 0..l {
                for tj in 0..self.tile_cols {
                    for &x in self.row_segment(ti, r, tj) {
                        acc += (x as f64) * (x as f64);
                    }
                }
            }
        }
        acc.sqrt()
    }

    /// Download to a host matrix, cropped to the logical shape — the one
    /// host transfer an expression result pays, at the very end.
    pub fn to_matrix(&self) -> Matrix {
        let l = self.lonum;
        let mut out = Matrix::zeros(self.logical_rows, self.logical_cols);
        for ti in 0..self.tile_rows {
            for tj in 0..self.tile_cols {
                let data = &self.tiles[ti * self.tile_cols + tj].data;
                for r in 0..l {
                    let gr = ti * l + r;
                    if gr >= self.logical_rows {
                        break;
                    }
                    let c0 = tj * l;
                    if c0 >= self.logical_cols {
                        break;
                    }
                    let w = l.min(self.logical_cols - c0);
                    out.data_mut()[gr * self.logical_cols + c0..][..w]
                        .copy_from_slice(&data[r * l..r * l + w]);
                }
            }
        }
        out
    }
}

/// Evict LRU-first unpinned tiles until `incoming` fits the budget.
/// Returns the eviction count.  Pinned tiles surfacing at the queue front
/// are re-queued as recently used (they *are* in use); if everything
/// resident is pinned the pool is allowed to overflow — a real allocator
/// cannot free memory the kernels are reading either.
fn evict_for(inner: &mut Inner, budget: usize, incoming: usize) -> usize {
    let mut evicted = 0usize;
    let mut requeued = 0usize;
    while inner.bytes.saturating_add(incoming) > budget {
        let Some(front) = inner.queue.pop_front() else {
            break;
        };
        let live = inner
            .map
            .get(&front.key)
            .is_some_and(|s| s.seq == front.seq);
        if !live {
            continue; // stale lazy-deletion record
        }
        let is_pinned = inner.op_pinned(&front.key.op)
            || inner
                .map
                .get(&front.key)
                .is_some_and(|s| Arc::strong_count(&s.handle) > 1);
        if is_pinned {
            inner.queue.push_back(front);
            requeued += 1;
            if requeued > inner.queue.len() {
                break; // every resident tile is pinned
            }
            continue;
        }
        if let Some(s) = inner.map.remove(&front.key) {
            inner.bytes -= s.handle.data.len() * std::mem::size_of::<f32>();
        }
        evicted += 1;
        requeued = 0;
    }
    if evicted > 0 {
        inner.stats.evictions += evicted as u64;
        telemetry::global().add("spamm.residency.evictions", evicted as u64);
    }
    evicted
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(i: u64) -> Fingerprint {
        Fingerprint(i, !i)
    }

    fn key(op: u64, t: (usize, usize)) -> TileKey {
        TileKey::new(fp(op), t)
    }

    /// 4 f32 per tile → 16 bytes per tile in every test below.
    const ELEMS: usize = 4;
    const TILE_BYTES: u64 = 16;

    #[test]
    fn miss_uploads_then_hits_skip_transfer() {
        let pool = ResidencyPool::new(0);
        let a = pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(2.0));
        assert!(!a.hit);
        assert_eq!(a.handle.data, vec![2.0; ELEMS]);
        let b = pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!("must not re-upload"));
        assert!(b.hit);
        assert_eq!(b.handle.data, vec![2.0; ELEMS]);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.uploaded_bytes, TILE_BYTES);
        assert_eq!(s.saved_bytes, TILE_BYTES);
        assert_eq!(s.resident_tiles, 1);
    }

    #[test]
    fn distinct_operands_do_not_collide() {
        let pool = ResidencyPool::new(0);
        pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        let b = pool.acquire(key(2, (0, 0)), ELEMS, |d| d.fill(2.0));
        assert!(!b.hit, "same coordinate, different operand content");
        assert_eq!(pool.resident_tiles(), 2);
    }

    #[test]
    fn lru_eviction_under_byte_budget() {
        // Budget of two tiles; third insert evicts the least recently used.
        let pool = ResidencyPool::new(2 * TILE_BYTES as usize);
        pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        pool.acquire(key(1, (0, 1)), ELEMS, |d| d.fill(2.0));
        // Touch (0,0) so (0,1) becomes LRU.
        pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!("hit expected"));
        let c = pool.acquire(key(1, (0, 2)), ELEMS, |d| d.fill(3.0));
        assert_eq!(c.evicted, 1);
        assert_eq!(pool.resident_bytes(), 2 * TILE_BYTES as usize);
        // (0,1) was evicted, (0,0) survived.
        assert!(pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0)).hit);
        assert!(!pool.acquire(key(1, (0, 1)), ELEMS, |d| d.fill(2.0)).hit);
        assert!(pool.stats().evictions >= 1);
    }

    #[test]
    fn pinned_tiles_are_never_evicted() {
        let pool = ResidencyPool::new(TILE_BYTES as usize); // one-tile budget
        let held = pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        // Second insert cannot evict the pinned tile: the pool overflows.
        let b = pool.acquire(key(1, (0, 1)), ELEMS, |d| d.fill(2.0));
        assert_eq!(b.evicted, 0, "pinned tile must not be evicted");
        assert!(pool.resident_bytes() > pool.budget_bytes());
        // The held handle still reads the original data.
        assert_eq!(held.handle.data, vec![1.0; ELEMS]);
        assert!(pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!()).hit);
        drop(held);
        drop(b);
        // Unpinned now: the next insert can evict down toward the budget.
        let c = pool.acquire(key(1, (0, 2)), ELEMS, |d| d.fill(3.0));
        assert!(c.evicted >= 1);
        assert!(pool.resident_bytes() <= pool.budget_bytes());
    }

    #[test]
    fn clear_keeps_pinned_tiles() {
        let pool = ResidencyPool::new(0);
        let held = pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        pool.acquire(key(1, (0, 1)), ELEMS, |d| d.fill(2.0));
        pool.clear();
        assert_eq!(pool.resident_tiles(), 1, "only the pinned tile survives");
        assert!(pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!()).hit);
        drop(held);
    }

    #[test]
    fn pinned_operand_tiles_are_never_evicted() {
        // One-tile budget; the pinned operand's tiles survive arbitrary
        // churn from other operands even with no live handles.
        let pool = ResidencyPool::new(TILE_BYTES as usize);
        pool.pin_operand(fp(1));
        pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        for i in 0..4usize {
            pool.acquire(key(2, (i, 0)), ELEMS, |d| d.fill(2.0));
        }
        assert!(
            pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!("evicted")).hit,
            "pinned operand must stay resident"
        );
        // Unpin: now it is ordinary LRU prey.
        assert!(!pool.unpin_operand(fp(1)), "last pin released");
        pool.acquire(key(2, (9, 0)), ELEMS, |d| d.fill(3.0));
        pool.acquire(key(2, (10, 0)), ELEMS, |d| d.fill(3.0));
        assert!(!pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0)).hit);
    }

    #[test]
    fn operand_pins_are_counted() {
        let pool = ResidencyPool::new(0);
        pool.pin_operand(fp(7));
        pool.pin_operand(fp(7));
        assert_eq!(pool.pinned_operands(), 1);
        assert!(pool.unpin_operand(fp(7)), "one pin left");
        assert!(!pool.unpin_operand(fp(7)));
        assert_eq!(pool.pinned_operands(), 0);
        // Unpinning an unpinned operand is a no-op.
        assert!(!pool.unpin_operand(fp(8)));
    }

    #[test]
    fn clear_keeps_pinned_operands() {
        let pool = ResidencyPool::new(0);
        pool.pin_operand(fp(1));
        pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        pool.acquire(key(2, (0, 0)), ELEMS, |d| d.fill(2.0));
        pool.clear();
        assert_eq!(pool.resident_tiles(), 1);
        assert!(pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!()).hit);
    }

    #[test]
    fn insert_registers_without_upload_counters() {
        let pool = ResidencyPool::new(0);
        let h = pool.insert(key(1, (0, 0)), vec![2.0; ELEMS]);
        assert_eq!(h.data, vec![2.0; ELEMS]);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "device-produced tile: no transfer");
        assert_eq!(s.uploaded_bytes, 0);
        assert_eq!(s.resident_tiles, 1);
        // A later acquire of the same key is a zero-transfer hit.
        let a = pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!("must hit"));
        assert!(a.hit);
        assert_eq!(a.handle.data, vec![2.0; ELEMS]);
        // Replacing updates the content and keeps bytes balanced.
        drop((h, a));
        pool.insert(key(1, (0, 0)), vec![3.0; ELEMS]);
        assert_eq!(pool.resident_bytes(), TILE_BYTES as usize);
        assert!(pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!()).handle.data[0] == 3.0);
    }

    #[test]
    fn patch_operand_rekeys_unchanged_and_uploads_changed() {
        let pool = ResidencyPool::new(0);
        pool.insert(key(1, (0, 0)), vec![1.0; ELEMS]);
        pool.insert(key(1, (0, 1)), vec![2.0; ELEMS]);
        // Packed payloads: one of a changed tile (stale after the
        // update), one of an unchanged tile (still valid).
        pool.insert(TileKey::packed(fp(1), (0, 1)), vec![1.0, 0.0, 2.0]);
        pool.insert(TileKey::packed(fp(1), (1, 0)), vec![1.0, 3.0, 4.0]);
        let before = pool.stats();
        let out = pool.patch_operand(fp(1), fp(2), &[(0, 1)], ELEMS, |t, buf| {
            assert_eq!(t, (0, 1), "only the changed resident dense tile fills");
            buf.fill(9.0);
        });
        assert_eq!(out.uploaded_tiles, 1);
        assert_eq!(out.uploaded_bytes, TILE_BYTES);
        assert_eq!(out.rekeyed_tiles, 2, "(0,0) dense + (1,0) packed");
        assert_eq!(out.dropped_stale, 1, "stale packed (0,1) dropped");
        let s = pool.stats();
        assert_eq!(s.uploaded_bytes - before.uploaded_bytes, TILE_BYTES);
        // Old fingerprint fully vacated; new one resident.
        assert!(pool.resident_tiles_of(fp(1)).is_empty());
        let mut tiles = pool.resident_tiles_of(fp(2));
        tiles.sort_unstable();
        assert_eq!(tiles, vec![(0, 0), (0, 1)]);
        // Changed tile carries the new content; unchanged survived bitwise.
        let got = pool.acquire(key(2, (0, 1)), ELEMS, |_| panic!("must be resident"));
        assert!(got.hit);
        assert_eq!(got.handle.data, vec![9.0; ELEMS]);
        let got = pool.acquire(key(2, (0, 0)), ELEMS, |_| panic!("must be resident"));
        assert_eq!(got.handle.data, vec![1.0; ELEMS]);
        // Byte accounting: two dense tiles + the surviving packed payload.
        assert_eq!(
            pool.resident_bytes(),
            2 * TILE_BYTES as usize + 12,
            "dropped packed payload released its bytes"
        );
    }

    #[test]
    fn patch_operand_migrates_pin_counts() {
        let pool = ResidencyPool::new(0);
        pool.insert(key(5, (0, 0)), vec![1.0; ELEMS]);
        pool.pin_operand(fp(5));
        pool.pin_operand(fp(5));
        let out = pool.patch_operand(fp(5), fp(6), &[], ELEMS, |_, _| {
            panic!("no changed tiles — fill must not run")
        });
        assert_eq!(out.rekeyed_tiles, 1);
        assert_eq!(out.uploaded_bytes, 0);
        assert_eq!(pool.pinned_operands(), 1);
        // Both pins moved: the first unpin keeps the operand pinned.
        assert!(pool.unpin_operand(fp(6)), "one migrated pin left");
        assert!(!pool.unpin_operand(fp(6)));
        // Patching an operand with nothing resident is a harmless no-op.
        let out = pool.patch_operand(fp(40), fp(41), &[(0, 0)], ELEMS, |_, _| {
            panic!("nothing resident — fill must not run")
        });
        assert_eq!(out, PatchOutcome::default());
    }

    #[test]
    fn packed_format_keys_do_not_collide_and_account_actual_bytes() {
        let pool = ResidencyPool::new(0);
        pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        // Same operand + tile, packed layout: distinct entry, 3-word payload.
        let p = pool.acquire_with(TileKey::packed(fp(1), (0, 0)), || vec![1.0, 0.0, 5.0]);
        assert!(!p.hit, "packed payload is a separate resident entry");
        assert_eq!(pool.resident_tiles(), 2);
        let s = pool.stats();
        assert_eq!(s.uploaded_bytes, TILE_BYTES + 12, "packed upload = payload len · 4");
        // Re-acquire hits and credits the packed (not dense) size.
        let q = pool.acquire_with(TileKey::packed(fp(1), (0, 0)), || panic!("must hit"));
        assert!(q.hit);
        assert_eq!(q.handle.data, vec![1.0, 0.0, 5.0]);
        assert_eq!(pool.stats().saved_bytes, 12);
        // Placement probes count only dense-layout tiles.
        assert_eq!(pool.resident_tiles_of(fp(1)), vec![(0, 0)]);
    }

    #[test]
    fn remove_operand_frees_unpinned_tiles_only() {
        let pool = ResidencyPool::new(0);
        let held = pool.insert(key(1, (0, 0)), vec![1.0; ELEMS]);
        pool.insert(key(1, (0, 1)), vec![1.0; ELEMS]);
        pool.acquire(key(2, (0, 0)), ELEMS, |d| d.fill(2.0));
        // One tile of operand 1 is pinned by the live handle.
        assert_eq!(pool.remove_operand(fp(1)), 1);
        assert_eq!(pool.resident_tiles(), 2);
        assert!(pool.acquire(key(1, (0, 0)), ELEMS, |_| panic!()).hit);
        drop(held);
        // Now fully unpinned: both remaining operand-1 tiles go.
        assert_eq!(pool.remove_operand(fp(1)), 1);
        assert_eq!(pool.resident_tiles(), 1, "operand 2 untouched");
        // Store-pinned operands are never removed.
        pool.pin_operand(fp(2));
        assert_eq!(pool.remove_operand(fp(2)), 0);
        assert_eq!(pool.resident_tiles(), 1);
    }

    #[test]
    fn resident_operand_roundtrips_and_norms() {
        use crate::matrix::tiling::PaddedMatrix;
        use crate::spamm::normmap::normmap;

        let m = Matrix::randn(40, 70, 12); // padded 64x96: 2x3 tile grid
        let p = PaddedMatrix::new(&m, 32);
        let mut tiles = Vec::new();
        let mut buf = vec![0.0f32; 32 * 32];
        for ti in 0..p.tile_rows() {
            for tj in 0..p.tile_cols() {
                p.copy_tile(ti, tj, &mut buf);
                tiles.push(((ti, tj), buf.clone()));
            }
        }
        let pool = ResidencyPool::new(0);
        let r = ResidentOperand::from_tiles(
            fp(9),
            32,
            m.rows(),
            m.cols(),
            p.tile_rows(),
            p.tile_cols(),
            tiles,
            Some(&pool),
        )
        .unwrap();
        assert_eq!(pool.resident_tiles(), 6);
        // Download equals the source bitwise; fnorm matches Matrix::fnorm.
        let back = r.to_matrix();
        assert_eq!(back.data(), m.data());
        assert_eq!(r.fnorm().to_bits(), m.fnorm().to_bits());
        // Device-side norms equal the host normmap bitwise.
        let nm = normmap(&p);
        for ti in 0..2 {
            for tj in 0..3 {
                assert_eq!(r.normmap()[(ti, tj)].to_bits(), nm[(ti, tj)].to_bits());
            }
        }
        // Retirement: drop the operand, then the pool can free its tiles.
        drop(r);
        assert_eq!(pool.remove_operand(fp(9)), 6);
        assert_eq!(pool.resident_tiles(), 0);
    }

    #[test]
    fn residency_probes_report_without_touching_lru() {
        let pool = ResidencyPool::new(2 * TILE_BYTES as usize);
        pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0));
        pool.acquire(key(1, (0, 1)), ELEMS, |d| d.fill(2.0));
        // Probe (0,0): must report it without marking it recently used.
        assert_eq!(
            pool.resident_bytes_of(fp(1), &[(0, 0), (7, 7)]),
            TILE_BYTES as usize
        );
        let mut tiles = pool.resident_tiles_of(fp(1));
        tiles.sort_unstable();
        assert_eq!(tiles, vec![(0, 0), (0, 1)]);
        assert!(pool.resident_tiles_of(fp(2)).is_empty());
        // (0,0) is still LRU despite the probes: the next insert evicts it.
        pool.acquire(key(1, (0, 2)), ELEMS, |d| d.fill(3.0));
        assert!(!pool.acquire(key(1, (0, 0)), ELEMS, |d| d.fill(1.0)).hit);
    }

    #[test]
    fn pool_is_sync_across_threads() {
        let pool = std::sync::Arc::new(ResidencyPool::new(0));
        let hs: Vec<_> = (0..4u64)
            .map(|t| {
                let pool = pool.clone();
                std::thread::spawn(move || {
                    for i in 0..64usize {
                        pool.acquire(key(t % 2, (i, 0)), ELEMS, |d| d.fill(i as f32));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // 2 operands × 64 tiles resident; every later acquire is a hit.
        assert_eq!(pool.resident_tiles(), 128);
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, 256);
        assert_eq!(s.misses, 128);
    }
}
