//! Single-threaded runtime: PJRT CPU client + per-artifact executable
//! cache.  Used directly by the single-device engine and (one instance per
//! worker thread) by the device simulator.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::runtime::artifact::ArtifactBundle;
use crate::runtime::literal::{
    literal_f32, literal_scalar, literal_to_matrix, literal_to_vec, matrix_to_literal,
};

/// A PJRT client plus lazily-compiled executables for one "device".
pub struct Runtime {
    client: xla::PjRtClient,
    bundle: ArtifactBundle,
    cache: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    /// Cumulative seconds spent inside `execute` (the modeled device-busy
    /// clock used for scaling reports).
    busy: RefCell<f64>,
    /// Cumulative seconds spent compiling (excluded from busy).
    compile_time: RefCell<f64>,
    /// Number of fresh executable compiles (cache misses in the
    /// per-artifact executable cache).  A warm runtime serving repeated
    /// requests holds this constant — the counter the serving tier's
    /// zero-recompile regression pins.
    compile_count: std::cell::Cell<u64>,
}

impl Runtime {
    pub fn new(bundle: &ArtifactBundle) -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            bundle: bundle.clone(),
            cache: RefCell::new(BTreeMap::new()),
            busy: RefCell::new(0.0),
            compile_time: RefCell::new(0.0),
            compile_count: std::cell::Cell::new(0),
        })
    }

    pub fn bundle(&self) -> &ArtifactBundle {
        &self.bundle
    }

    /// Seconds this runtime has spent executing computations.
    pub fn busy_secs(&self) -> f64 {
        *self.busy.borrow()
    }

    pub fn compile_secs(&self) -> f64 {
        *self.compile_time.borrow()
    }

    /// Executables compiled so far (executable-cache misses).  The delta
    /// across one call is zero exactly when the call ran entirely on
    /// already-compiled artifacts.
    pub fn compiles(&self) -> u64 {
        self.compile_count.get()
    }

    pub fn reset_busy(&self) {
        *self.busy.borrow_mut() = 0.0;
    }

    /// Compile (or fetch from cache) the named artifact.
    fn executable(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let meta = self.bundle.get(name)?;
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&meta.file)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        *self.compile_time.borrow_mut() += t.elapsed().as_secs_f64();
        self.compile_count.set(self.compile_count.get() + 1);
        log::debug!(
            "compiled {name} in {:.1} ms",
            t.elapsed().as_secs_f64() * 1e3
        );
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of artifacts (startup warm-up).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute the named artifact on literal inputs; returns the flattened
    /// output tuple (python lowers everything with return_tuple=True).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.execute_refs(name, &refs)
    }

    /// Execute on *borrowed* literals — the buffer-handle path: callers
    /// holding device-resident buffers (e.g. the devicesim staging store)
    /// execute without copying them into owned inputs first.
    pub fn execute_refs(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.executable(name)?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let t = Instant::now();
        let result = exe.execute::<&xla::Literal>(inputs)?;
        let root = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Xla(format!("{name}: empty execution result")))?
            .to_literal_sync()?;
        *self.busy.borrow_mut() += t.elapsed().as_secs_f64();
        let meta = self.bundle.get(name)?;
        let mut root = root;
        let outs = root.decompose_tuple()?;
        if outs.len() != meta.n_outputs {
            return Err(Error::Xla(format!(
                "{name}: expected {} outputs, got {}",
                meta.n_outputs,
                outs.len()
            )));
        }
        Ok(outs)
    }

    // ---- typed wrappers over the artifact grid ---------------------------

    /// get-norm: n×n matrix → BDIM×BDIM normmap at tile size `lonum`.
    pub fn getnorm(&self, m: &Matrix, lonum: usize, mxu: bool) -> Result<Matrix> {
        let meta = self.bundle.getnorm(m.rows(), lonum, mxu)?;
        let name = meta.name.clone();
        let out = self.execute(&name, &[matrix_to_literal(m)?])?;
        literal_to_matrix(&out[0])
    }

    /// Dense baseline: C = A·B via the XLA dense artifact.  Square sizes
    /// resolve by name; anything else (the rectangular CNN-layer GEMMs)
    /// resolves by compiled input shape.
    pub fn dense(&self, a: &Matrix, b: &Matrix, precision: &str) -> Result<Matrix> {
        let name = if a.rows() == a.cols() && a.rows() == b.rows() && b.rows() == b.cols() {
            self.bundle.dense(a.rows(), precision)?.name.clone()
        } else {
            self.bundle
                .dense_shaped(a.rows(), a.cols(), b.cols(), precision)?
                .name
                .clone()
        };
        let out = self.execute(&name, &[matrix_to_literal(a)?, matrix_to_literal(b)?])?;
        literal_to_matrix(&out[0])
    }

    /// Batched tile GEMM on pre-gathered (batch·L², padded) buffers.
    /// Returns the product buffer (batch·L²).
    pub fn tile_gemm(
        &self,
        a_tiles: &[f32],
        b_tiles: &[f32],
        batch: usize,
        lonum: usize,
        precision: &str,
    ) -> Result<Vec<f32>> {
        let dims = [batch, lonum, lonum];
        let out = self.execute(
            &self.bundle.tilegemm(batch, lonum, precision)?.name.clone(),
            &[literal_f32(&dims, a_tiles)?, literal_f32(&dims, b_tiles)?],
        )?;
        let (_, data) = literal_to_vec(&out[0])?;
        Ok(data)
    }

    /// Batched tile linear combination C[b] = α·X[b] + β·Y[b] on
    /// pre-gathered (batch·L², padded) buffers — the device-side combine
    /// expression graphs use (e.g. McWeeny's 3P² − 2P³) so chained
    /// iterations never leave the device.
    pub fn tile_axpby(
        &self,
        x_tiles: &[f32],
        y_tiles: &[f32],
        alpha: f32,
        beta: f32,
        batch: usize,
        lonum: usize,
    ) -> Result<Vec<f32>> {
        let dims = [batch, lonum, lonum];
        let out = self.execute(
            &self.bundle.axpby(batch, lonum)?.name.clone(),
            &[
                literal_f32(&dims, x_tiles)?,
                literal_f32(&dims, y_tiles)?,
                literal_scalar(alpha)?,
                literal_scalar(beta)?,
            ],
        )?;
        let (_, data) = literal_to_vec(&out[0])?;
        Ok(data)
    }

    /// Sparse tile product over COO entry lists: C[l,l] = A·B where A is
    /// l×(run·l) and B is (run·l)×l, entries given as parallel
    /// (linear-index, value) arrays in row-major scan order.  The run
    /// width must match an artifact bucket exactly — index encoding
    /// depends on the contraction width, so callers pick the bucket (via
    /// [`ArtifactBundle::sptile_runs`]) *before* packing indices.  Arrays
    /// are zero-padded to the artifact capacity here; live counts travel
    /// in the 2-entry meta input.
    pub fn sptile(
        &self,
        a_idx: &[f32],
        a_vals: &[f32],
        b_idx: &[f32],
        b_vals: &[f32],
        run: usize,
        lonum: usize,
    ) -> Result<Vec<f32>> {
        let meta = self.bundle.sptile(run, lonum)?;
        let name = meta.name.clone();
        let art_run = meta.param_usize("run").unwrap_or(0);
        let cap = meta.param_usize("cap").unwrap_or(0);
        if art_run != run {
            return Err(Error::Artifact(format!(
                "sptile: no exact bucket for run {run} at lonum {lonum} (closest {art_run})"
            )));
        }
        if a_vals.len() != a_idx.len() || b_vals.len() != b_idx.len() {
            return Err(Error::Shape(
                "sptile: values/indices length mismatch".into(),
            ));
        }
        if a_vals.len() > cap || b_vals.len() > cap {
            return Err(Error::Shape(format!(
                "sptile: nnz ({}, {}) exceeds capacity {cap}",
                a_vals.len(),
                b_vals.len()
            )));
        }
        let pad = |src: &[f32]| {
            let mut v = vec![0.0f32; cap];
            v[..src.len()].copy_from_slice(src);
            v
        };
        let counts = [a_vals.len() as f32, b_vals.len() as f32];
        let out = self.execute(
            &name,
            &[
                literal_f32(&[cap], &pad(a_vals))?,
                literal_f32(&[cap], &pad(a_idx))?,
                literal_f32(&[cap], &pad(b_vals))?,
                literal_f32(&[cap], &pad(b_idx))?,
                literal_f32(&[2], &counts)?,
            ],
        )?;
        let (_, data) = literal_to_vec(&out[0])?;
        Ok(data)
    }

    /// On-device τ search (§3.5.2): normmaps + target ratio → (τ, ratio).
    pub fn tune(&self, na: &Matrix, nb: &Matrix, target: f32) -> Result<(f32, f32)> {
        let bdim = na.rows();
        let name = self.bundle.tune(bdim)?.name.clone();
        let out = self.execute(
            &name,
            &[
                matrix_to_literal(na)?,
                matrix_to_literal(nb)?,
                literal_scalar(target)?,
            ],
        )?;
        let tau = out[0].to_vec::<f32>()?[0];
        let ratio = out[1].to_vec::<f32>()?[0];
        Ok((tau, ratio))
    }

    /// Fused single-call SpAMM (numerics oracle / small problems).
    pub fn spamm_fused(
        &self,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
        precision: &str,
    ) -> Result<Matrix> {
        let name = self.bundle.spamm_fused(a.rows(), precision)?.name.clone();
        let out = self.execute(
            &name,
            &[
                matrix_to_literal(a)?,
                matrix_to_literal(b)?,
                literal_scalar(tau)?,
            ],
        )?;
        literal_to_matrix(&out[0])
    }
}
