//! Additional sparse storage formats — COO and CSC, the other formats
//! cuSPARSE supports (paper §5.2), plus format conversions.  Used by the
//! SpMM kernel and the format-conversion ablation.

use super::CsrMatrix;
use crate::error::{Error, Result};
use crate::matrix::Matrix;

/// Coordinate-format sparse matrix (row, col, value triplets).
#[derive(Clone, Debug, PartialEq)]
pub struct CooMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Triplets sorted by (row, col).
    pub entries: Vec<(usize, usize, f32)>,
}

impl CooMatrix {
    pub fn from_dense(m: &Matrix, threshold: f32) -> CooMatrix {
        let mut entries = Vec::new();
        for r in 0..m.rows() {
            for (c, &x) in m.row(r).iter().enumerate() {
                if x != 0.0 && x.abs() >= threshold {
                    entries.push((r, c, x));
                }
            }
        }
        CooMatrix {
            rows: m.rows(),
            cols: m.cols(),
            entries,
        }
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    pub fn to_csr(&self) -> CsrMatrix {
        let mut indptr = vec![0usize; self.rows + 1];
        for &(r, _, _) in &self.entries {
            indptr[r + 1] += 1;
        }
        for r in 0..self.rows {
            indptr[r + 1] += indptr[r];
        }
        let mut indices = vec![0usize; self.entries.len()];
        let mut values = vec![0.0f32; self.entries.len()];
        let mut cursor = indptr.clone();
        for &(r, c, v) in &self.entries {
            indices[cursor[r]] = c;
            values[cursor[r]] = v;
            cursor[r] += 1;
        }
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for &(r, c, v) in &self.entries {
            m[(r, c)] = v;
        }
        m
    }
}

/// Compressed Sparse Column matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Column pointer array, length cols+1.
    pub indptr: Vec<usize>,
    /// Row indices, sorted within each column.
    pub indices: Vec<usize>,
    pub values: Vec<f32>,
}

impl CscMatrix {
    /// CSC of M = CSR of Mᵀ with rows/cols swapped back.
    pub fn from_csr(csr: &CsrMatrix) -> CscMatrix {
        let mut indptr = vec![0usize; csr.cols + 1];
        for &c in &csr.indices {
            indptr[c + 1] += 1;
        }
        for c in 0..csr.cols {
            indptr[c + 1] += indptr[c];
        }
        let mut indices = vec![0usize; csr.nnz()];
        let mut values = vec![0.0f32; csr.nnz()];
        let mut cursor = indptr.clone();
        for r in 0..csr.rows {
            for i in csr.indptr[r]..csr.indptr[r + 1] {
                let c = csr.indices[i];
                indices[cursor[c]] = r;
                values[cursor[c]] = csr.values[i];
                cursor[c] += 1;
            }
        }
        CscMatrix {
            rows: csr.rows,
            cols: csr.cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for c in 0..self.cols {
            for i in self.indptr[c]..self.indptr[c + 1] {
                m[(self.indices[i], c)] = self.values[i];
            }
        }
        m
    }
}

/// Pack one row-major tile buffer into the COO wire payload the executor
/// stages sparse-strategy tiles in: `[nnz, idx0, val0, idx1, val1, …]`,
/// entries in row-major scan order, `idx = r·cols + c` stored as an exact
/// f32 (tile linear indices stay far below 2²⁴, so the conversion is
/// lossless).  An entry is kept when `|x| > floor`; with `floor == 0.0`
/// the keep test is on the *bit pattern* instead (`to_bits() != 0`), so
/// `-0.0` survives and [`unpack_tile`] reproduces the tile bitwise.
pub fn pack_tile(tile: &[f32], cols: usize, floor: f32) -> Vec<f32> {
    let keep = |x: f32| {
        if floor == 0.0 {
            x.to_bits() != 0
        } else {
            x.abs() > floor
        }
    };
    debug_assert_eq!(tile.len() % cols.max(1), 0);
    let mut out = vec![0.0f32];
    let mut nnz = 0usize;
    for (i, &x) in tile.iter().enumerate() {
        if keep(x) {
            out.push(i as f32);
            out.push(x);
            nnz += 1;
        }
    }
    out[0] = nnz as f32;
    out
}

/// Entry count of a [`pack_tile`] payload.
pub fn packed_nnz(packed: &[f32]) -> usize {
    packed.first().map(|&n| n as usize).unwrap_or(0)
}

/// Scatter a [`pack_tile`] payload back into a zeroed row-major buffer of
/// `elems` entries.  Inverse of `pack_tile` bitwise when packing used a
/// zero floor (dropped entries were exactly `+0.0`).
pub fn unpack_tile(packed: &[f32], elems: usize, dst: &mut [f32]) -> Result<()> {
    if dst.len() < elems {
        return Err(Error::Shape(format!(
            "unpack_tile: dst {} < elems {elems}",
            dst.len()
        )));
    }
    let nnz = packed_nnz(packed);
    if packed.len() < 1 + 2 * nnz {
        return Err(Error::Shape(format!(
            "unpack_tile: payload {} too short for nnz {nnz}",
            packed.len()
        )));
    }
    dst[..elems].fill(0.0);
    for pair in packed[1..1 + 2 * nnz].chunks_exact(2) {
        let idx = pair[0] as usize;
        if idx >= elems {
            return Err(Error::Shape(format!(
                "unpack_tile: index {idx} out of range {elems}"
            )));
        }
        dst[idx] = pair[1];
    }
    Ok(())
}

/// Convert a packed tile payload to a [`CooMatrix`] over the tile's
/// logical (rows × cols) shape — the bridge from the staged wire format
/// to the [`spgemm`](crate::sparse::spgemm::spgemm) host kernel.
pub fn packed_to_coo(packed: &[f32], rows: usize, cols: usize) -> Result<CooMatrix> {
    let nnz = packed_nnz(packed);
    if packed.len() < 1 + 2 * nnz {
        return Err(Error::Shape(format!(
            "packed_to_coo: payload {} too short for nnz {nnz}",
            packed.len()
        )));
    }
    let mut entries = Vec::with_capacity(nnz);
    for pair in packed[1..1 + 2 * nnz].chunks_exact(2) {
        let idx = pair[0] as usize;
        if idx >= rows * cols {
            return Err(Error::Shape(format!(
                "packed_to_coo: index {idx} out of range {rows}x{cols}"
            )));
        }
        entries.push((idx / cols, idx % cols, pair[1]));
    }
    Ok(CooMatrix { rows, cols, entries })
}

/// SpMM: sparse (CSR) × dense → dense — cuSPARSE's sparse-dense workhorse,
/// used when only one operand of a near-sparse product truncates well.
pub fn spmm(a: &CsrMatrix, b: &Matrix) -> Result<Matrix> {
    if a.cols != b.rows() {
        return Err(Error::Shape(format!(
            "spmm: {}x{} @ {}x{}",
            a.rows,
            a.cols,
            b.rows(),
            b.cols()
        )));
    }
    let n = b.cols();
    let mut out = Matrix::zeros(a.rows, n);
    for r in 0..a.rows {
        for i in a.indptr[r]..a.indptr[r + 1] {
            let k = a.indices[i];
            let av = a.values[i];
            let brow = b.row(k);
            let orow = &mut out.data_mut()[r * n..(r + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse_dense_pair() -> (Matrix, Matrix) {
        let mut a = Matrix::randn(20, 15, 1);
        a.truncate(0.9);
        let b = Matrix::randn(15, 12, 2);
        (a, b)
    }

    #[test]
    fn coo_roundtrip() {
        let (a, _) = sparse_dense_pair();
        let coo = CooMatrix::from_dense(&a, 0.0);
        assert_eq!(coo.to_dense(), a);
        assert_eq!(coo.nnz(), CsrMatrix::from_dense(&a, 0.0).nnz());
    }

    #[test]
    fn coo_to_csr_equals_direct() {
        let (a, _) = sparse_dense_pair();
        let via_coo = CooMatrix::from_dense(&a, 0.0).to_csr();
        let direct = CsrMatrix::from_dense(&a, 0.0);
        assert_eq!(via_coo, direct);
        via_coo.validate().unwrap();
    }

    #[test]
    fn csc_roundtrip() {
        let (a, _) = sparse_dense_pair();
        let csr = CsrMatrix::from_dense(&a, 0.0);
        let csc = CscMatrix::from_csr(&csr);
        assert_eq!(csc.nnz(), csr.nnz());
        assert_eq!(csc.to_dense(), a);
    }

    #[test]
    fn spmm_matches_dense() {
        let (a, b) = sparse_dense_pair();
        let csr = CsrMatrix::from_dense(&a, 0.0);
        let got = spmm(&csr, &b).unwrap();
        let want = a.matmul(&b).unwrap();
        assert!(got.error_fnorm(&want).unwrap() < 1e-4);
    }

    #[test]
    fn spmm_shape_mismatch() {
        let csr = CsrMatrix::from_dense(&Matrix::zeros(3, 4), 0.0);
        assert!(spmm(&csr, &Matrix::zeros(5, 2)).is_err());
    }

    #[test]
    fn pack_tile_roundtrips_bitwise_at_zero_floor() {
        let mut tile = vec![0.0f32; 8 * 8];
        tile[3] = 1.5;
        tile[9] = -0.0; // negative zero must survive a zero-floor pack
        tile[63] = -2.5;
        let packed = pack_tile(&tile, 8, 0.0);
        assert_eq!(packed_nnz(&packed), 3);
        let mut back = vec![7.0f32; 8 * 8];
        unpack_tile(&packed, 64, &mut back).unwrap();
        for (a, b) in tile.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Positive floor drops sub-floor magnitudes.
        let packed = pack_tile(&tile, 8, 2.0);
        assert_eq!(packed_nnz(&packed), 1);
    }

    #[test]
    fn packed_to_coo_matches_dense_scan() {
        let m = {
            let mut m = Matrix::randn(8, 8, 5);
            m.truncate(0.8);
            m
        };
        let packed = pack_tile(m.data(), 8, 0.0);
        let coo = packed_to_coo(&packed, 8, 8).unwrap();
        assert_eq!(coo.to_dense(), m);
        // Corrupt index caught.
        let mut bad = packed.clone();
        if packed_nnz(&bad) > 0 {
            bad[1] = 1e6;
            assert!(packed_to_coo(&bad, 8, 8).is_err());
            assert!(unpack_tile(&bad, 64, &mut vec![0.0; 64]).is_err());
        }
    }

    #[test]
    fn empty_matrices() {
        let z = Matrix::zeros(4, 4);
        let coo = CooMatrix::from_dense(&z, 0.0);
        assert_eq!(coo.nnz(), 0);
        assert_eq!(coo.to_csr().nnz(), 0);
        let csc = CscMatrix::from_csr(&coo.to_csr());
        assert_eq!(csc.to_dense(), z);
    }
}
