//! Gustavson-style SpGEMM (CSR × CSR → CSR), the `cusparseScsrgemm`
//! stand-in.  Classic row-wise algorithm with a dense accumulator per
//! output row: cost O(Σ_i Σ_{k∈A_i} nnz(B_k)) — grows with nnz², which is
//! exactly the behaviour Table 3 demonstrates makes sparse GEMM
//! uncompetitive on near-sparse matrices.

use super::CsrMatrix;
use crate::error::{Error, Result};

/// C = A · B over CSR operands.
pub fn spgemm(a: &CsrMatrix, b: &CsrMatrix) -> Result<CsrMatrix> {
    if a.cols != b.rows {
        return Err(Error::Shape(format!(
            "spgemm: {}x{} @ {}x{}",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let mut indptr = Vec::with_capacity(a.rows + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0);

    // Dense accumulator + occupancy list (Gustavson).
    let mut acc = vec![0.0f32; b.cols];
    let mut touched: Vec<usize> = Vec::with_capacity(b.cols);

    for r in 0..a.rows {
        for ai in a.indptr[r]..a.indptr[r + 1] {
            let k = a.indices[ai];
            let av = a.values[ai];
            for bi in b.indptr[k]..b.indptr[k + 1] {
                let c = b.indices[bi];
                if acc[c] == 0.0 && !touched.contains(&c) {
                    touched.push(c);
                }
                acc[c] += av * b.values[bi];
            }
        }
        touched.sort_unstable();
        for &c in &touched {
            // Keep explicit zeros out (cancellation) — matches cuSPARSE's
            // numeric phase behaviour closely enough for the comparison.
            if acc[c] != 0.0 {
                indices.push(c);
                values.push(acc[c]);
            }
            acc[c] = 0.0;
        }
        touched.clear();
        indptr.push(indices.len());
    }

    Ok(CsrMatrix {
        rows: a.rows,
        cols: b.cols,
        indptr,
        indices,
        values,
    })
}

/// FLOP count of the SpGEMM numeric phase (2 · Σ multiplies) — used by the
/// bench harness to report arithmetic intensity next to timings.
pub fn spgemm_flops(a: &CsrMatrix, b: &CsrMatrix) -> u64 {
    let mut fl = 0u64;
    for r in 0..a.rows {
        for ai in a.indptr[r]..a.indptr[r + 1] {
            let k = a.indices[ai];
            fl += 2 * (b.indptr[k + 1] - b.indptr[k]) as u64;
        }
    }
    fl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Matrix;

    #[test]
    fn matches_dense_reference() {
        let a = {
            let mut m = Matrix::randn(16, 12, 1);
            m.truncate(0.8); // make it sparse
            m
        };
        let b = {
            let mut m = Matrix::randn(12, 20, 2);
            m.truncate(0.8);
            m
        };
        let ca = CsrMatrix::from_dense(&a, 0.0);
        let cb = CsrMatrix::from_dense(&b, 0.0);
        let got = spgemm(&ca, &cb).unwrap();
        got.validate().unwrap();
        let want = a.matmul(&b).unwrap();
        assert!(got.to_dense().error_fnorm(&want).unwrap() < 1e-4);
    }

    #[test]
    fn identity_spgemm() {
        let i = CsrMatrix::from_dense(&Matrix::eye(8), 0.0);
        let a = CsrMatrix::from_dense(&Matrix::randn(8, 8, 3), 0.0);
        let c = spgemm(&i, &a).unwrap();
        assert_eq!(c.to_dense(), a.to_dense());
    }

    #[test]
    fn empty_times_anything_is_empty() {
        let z = CsrMatrix::from_dense(&Matrix::zeros(4, 4), 0.0);
        let a = CsrMatrix::from_dense(&Matrix::randn(4, 4, 4), 0.0);
        let c = spgemm(&z, &a).unwrap();
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = CsrMatrix::from_dense(&Matrix::zeros(4, 5), 0.0);
        let b = CsrMatrix::from_dense(&Matrix::zeros(4, 5), 0.0);
        assert!(spgemm(&a, &b).is_err());
    }

    #[test]
    fn flops_counts_multiplies() {
        // A row with 2 nnz hitting B rows with 3 and 1 nnz → 2·(3+1) flops.
        let mut am = Matrix::zeros(1, 2);
        am[(0, 0)] = 1.0;
        am[(0, 1)] = 1.0;
        let mut bm = Matrix::zeros(2, 4);
        bm[(0, 0)] = 1.0;
        bm[(0, 1)] = 1.0;
        bm[(0, 2)] = 1.0;
        bm[(1, 3)] = 1.0;
        let fl = spgemm_flops(
            &CsrMatrix::from_dense(&am, 0.0),
            &CsrMatrix::from_dense(&bm, 0.0),
        );
        assert_eq!(fl, 8);
    }
}
