//! Sparse-matrix substrate: CSR storage, truncation-based conversion, and
//! Gustavson SpGEMM — the cuSPARSE (`cusparseScsrgemm`) stand-in for the
//! Table 3 comparison.  Like the paper's baseline, the *format conversion
//! time is excluded* from benchmark timings; only the SpGEMM itself is
//! measured.

pub mod formats;
pub mod spgemm;

pub use formats::{pack_tile, packed_nnz, packed_to_coo, spmm, unpack_tile, CooMatrix, CscMatrix};
pub use spgemm::{spgemm, spgemm_flops};

use crate::error::{Error, Result};
use crate::matrix::Matrix;

/// Compressed Sparse Row matrix (f32).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array, length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub indices: Vec<usize>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Convert a dense matrix, keeping entries with |x| ≥ threshold.
    /// `threshold = 0.0` keeps all non-zeros exactly (the paper's TRUN
    /// truncation uses a positive threshold).
    pub fn from_dense(m: &Matrix, threshold: f32) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(m.rows() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..m.rows() {
            for (c, &x) in m.row(r).iter().enumerate() {
                if x != 0.0 && x.abs() >= threshold {
                    indices.push(c);
                    values.push(x);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows: m.rows(),
            cols: m.cols(),
            indptr,
            indices,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// nnz / (rows·cols) — the paper's *nz ratio* after truncation.
    pub fn nz_ratio(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.indptr[r]..self.indptr[r + 1] {
                m[(r, self.indices[i])] = self.values[i];
            }
        }
        m
    }

    /// Structural validation (sorted columns, in-range, monotone indptr).
    pub fn validate(&self) -> Result<()> {
        if self.indptr.len() != self.rows + 1 {
            return Err(Error::Shape("indptr length".into()));
        }
        if *self.indptr.last().unwrap() != self.indices.len()
            || self.indices.len() != self.values.len()
        {
            return Err(Error::Shape("nnz bookkeeping mismatch".into()));
        }
        for r in 0..self.rows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(Error::Shape(format!("indptr not monotone at row {r}")));
            }
            let slice = &self.indices[self.indptr[r]..self.indptr[r + 1]];
            for w in slice.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::Shape(format!("row {r} columns not sorted")));
                }
            }
            if let Some(&last) = slice.last() {
                if last >= self.cols {
                    return Err(Error::Shape(format!("row {r} column out of range")));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m[(0, 1)] = 1.5;
        m[(2, 0)] = -2.0;
        m[(2, 3)] = 0.25;
        let csr = CsrMatrix::from_dense(&m, 0.0);
        csr.validate().unwrap();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.to_dense(), m);
    }

    #[test]
    fn truncation_drops_small() {
        let mut m = Matrix::zeros(2, 2);
        m[(0, 0)] = 0.01;
        m[(1, 1)] = 1.0;
        let csr = CsrMatrix::from_dense(&m, 0.1);
        assert_eq!(csr.nnz(), 1);
        assert_eq!(csr.to_dense()[(1, 1)], 1.0);
        assert_eq!(csr.to_dense()[(0, 0)], 0.0);
        assert!((csr.nz_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix() {
        let csr = CsrMatrix::from_dense(&Matrix::zeros(4, 4), 0.0);
        csr.validate().unwrap();
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), Matrix::zeros(4, 4));
    }

    #[test]
    fn validate_catches_corruption() {
        let m = Matrix::randn(4, 4, 1);
        let mut csr = CsrMatrix::from_dense(&m, 0.5);
        if csr.nnz() >= 2 {
            csr.indices.swap(0, 1);
            // either unsorted or fine depending on values; force corruption:
            csr.indices[0] = 1000;
            assert!(csr.validate().is_err());
        }
    }
}
