//! Cross-module property tests (host-only — no XLA) using the crate's own
//! mini property-testing framework.  These pin the invariants DESIGN.md §7
//! lists.

use cuspamm::config::Balance;
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::proptest::{forall_ok, gen, PropConfig};
use cuspamm::spamm::balance::Assignment;
use cuspamm::spamm::normmap::normmap;
use cuspamm::spamm::reference::{spamm_flat_host, spamm_recursive};
use cuspamm::spamm::schedule::Schedule;
use cuspamm::spamm::tuner::{tune_tau, TuneParams};
use cuspamm::sparse::spgemm::spgemm;
use cuspamm::sparse::CsrMatrix;
use cuspamm::util::bf16;
use cuspamm::util::prng::Rng;

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        seed: 0xDECAF,
    }
}

#[test]
fn prop_spamm_tau_zero_is_exact_gemm() {
    forall_ok(
        cfg(12),
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 1, 80);
            let m = gen::usize_in(rng, 1, 80);
            let k = gen::usize_in(rng, 1, 80);
            let seed = rng.next_u64();
            (n, k, m, seed)
        },
        |&(n, k, m, seed)| {
            let a = Matrix::randn(n, k, seed);
            let b = Matrix::randn(k, m, seed ^ 1);
            let got = spamm_flat_host(&a, &b, 0.0, 16).map_err(|e| e.to_string())?;
            let want = a.matmul(&b).map_err(|e| e.to_string())?;
            let err = got.error_fnorm(&want).unwrap();
            let scale = want.fnorm().max(1.0);
            if err / scale > 1e-5 {
                return Err(format!("{n}x{k}x{m}: rel err {}", err / scale));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_error_monotone_in_tau() {
    forall_ok(
        cfg(10),
        |rng: &mut Rng| (gen::pow2_in(rng, 64, 128), rng.next_u64()),
        |&(n, seed)| {
            let a = Matrix::decay_exponential(n, 1.0, 0.5, seed);
            let b = Matrix::decay_exponential(n, 1.0, 0.5, seed ^ 7);
            let exact = a.matmul(&b).unwrap();
            let mut prev = -1.0f64;
            for tau in [0.0f32, 1e-4, 1e-2, 1.0, 100.0] {
                let c = spamm_flat_host(&a, &b, tau, 32).unwrap();
                let e = exact.error_fnorm(&c).unwrap();
                if e < prev - 1e-6 {
                    return Err(format!("n={n} τ={tau}: error dropped {prev} → {e}"));
                }
                prev = e;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_flat_error_at_most_recursive() {
    forall_ok(
        cfg(6),
        |rng: &mut Rng| (rng.next_u64(), gen::f32_in(rng, 1e-4, 1e-1)),
        |&(seed, tau)| {
            let a = Matrix::decay_exponential(64, 1.0, 0.5, seed);
            let b = Matrix::decay_exponential(64, 1.0, 0.5, seed ^ 3);
            let exact = a.matmul(&b).unwrap();
            let ef = exact
                .error_fnorm(&spamm_flat_host(&a, &b, tau, 16).unwrap())
                .unwrap();
            let er = exact
                .error_fnorm(&spamm_recursive(&a, &b, tau, 16).unwrap())
                .unwrap();
            if ef > er + 1e-3 {
                return Err(format!("flat {ef} > recursive {er} at τ={tau}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_schedule_counts_consistent() {
    forall_ok(
        cfg(30),
        |rng: &mut Rng| {
            let tr = gen::usize_in(rng, 1, 12);
            let tk = gen::usize_in(rng, 1, 12);
            let tc = gen::usize_in(rng, 1, 12);
            (tr, tk, tc, rng.next_u64(), gen::f32_in(rng, 0.0, 2.0))
        },
        |&(tr, tk, tc, seed, tau)| {
            let na = {
                let mut m = Matrix::randn(tr, tk, seed);
                for v in m.data_mut() {
                    *v = v.abs();
                }
                m
            };
            let nb = {
                let mut m = Matrix::randn(tk, tc, seed ^ 9);
                for v in m.data_mut() {
                    *v = v.abs();
                }
                m
            };
            let s = Schedule::build(&na, &nb, tau).map_err(|e| e.to_string())?;
            // total = Σ per-tile v == v_matrix sum == products iterator len
            let v_sum: f32 = s.v_matrix().data().iter().sum();
            if v_sum as usize != s.valid_products() {
                return Err("v_matrix sum != valid_products".into());
            }
            let it_count = s
                .products_for_tiles(
                    (0..tr).flat_map(|i| (0..tc).map(move |j| (i, j))),
                )
                .count();
            if it_count != s.valid_products() {
                return Err("iterator count != valid_products".into());
            }
            // every listed k really passes, every omitted k really fails
            for i in 0..tr {
                for j in 0..tc {
                    let ks = s.ks(i, j);
                    let mut idx = 0usize;
                    for k in 0..tk {
                        let pass = na[(i, k)] * nb[(k, j)] >= tau;
                        let listed = idx < ks.len() && ks[idx] == k as u32;
                        if listed {
                            idx += 1;
                        }
                        if pass != listed {
                            return Err(format!("tile ({i},{j}) k={k}: pass={pass} listed={listed}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_assignment_partitions_tiles() {
    forall_ok(
        cfg(30),
        |rng: &mut Rng| {
            let tr = gen::usize_in(rng, 1, 16);
            let tc = gen::usize_in(rng, 1, 16);
            let devices = gen::usize_in(rng, 1, 9);
            let strided = rng.next_f32() < 0.5;
            let stride = gen::usize_in(rng, 1, 6);
            (tr, tc, devices, strided, stride, rng.next_u64())
        },
        |&(tr, tc, devices, strided, stride, seed)| {
            let na = Matrix::randn(tr, 4, seed);
            let nb = Matrix::randn(4, tc, seed ^ 5);
            let s = Schedule::build(&na, &nb, f32::MAX).unwrap();
            let policy = if strided {
                Balance::Strided(stride)
            } else {
                Balance::RowBlock
            };
            let a = Assignment::build(&s, devices, policy);
            let mut seen = vec![0u8; tr * tc];
            for d in 0..devices {
                for (i, j) in a.tiles_of(&s, d) {
                    seen[i * tc + j] += 1;
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err(format!("{policy:?} {devices} devices: not a partition"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tuner_ratio_within_tolerance_or_quantization() {
    forall_ok(
        cfg(15),
        |rng: &mut Rng| {
            (
                gen::usize_in(rng, 2, 10),
                gen::f32_in(rng, 0.05, 0.95) as f64,
                rng.next_u64(),
            )
        },
        |&(bdim, target, seed)| {
            let mut na = Matrix::randn(bdim, bdim, seed);
            let mut nb = Matrix::randn(bdim, bdim, seed ^ 11);
            for v in na.data_mut() {
                *v = v.abs();
            }
            for v in nb.data_mut() {
                *v = v.abs();
            }
            let r = tune_tau(&na, &nb, target, TuneParams { max_iters: 40, tolerance: 0.0 })
                .map_err(|e| e.to_string())?;
            // Reachable ratios are multiples of 1/bdim³; allow quantization.
            let quantum = 1.0 / (bdim * bdim * bdim) as f64;
            if (r.achieved_ratio - target).abs() > quantum + 0.02 {
                return Err(format!(
                    "bdim={bdim} target={target}: achieved {}",
                    r.achieved_ratio
                ));
            }
            // Achieved ratio must be the Schedule's ratio at that τ.
            let s = Schedule::build(&na, &nb, r.tau).unwrap();
            if (s.valid_ratio() - r.achieved_ratio).abs() > 1e-9 {
                return Err("tuner/schedule ratio mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_csr_roundtrip_and_spgemm() {
    forall_ok(
        cfg(20),
        |rng: &mut Rng| {
            (
                gen::usize_in(rng, 1, 30),
                gen::usize_in(rng, 1, 30),
                gen::usize_in(rng, 1, 30),
                gen::f32_in(rng, 0.0, 1.5),
                rng.next_u64(),
            )
        },
        |&(m, k, n, thresh, seed)| {
            let mut a = Matrix::randn(m, k, seed);
            let mut b = Matrix::randn(k, n, seed ^ 13);
            a.truncate(thresh);
            b.truncate(thresh);
            let ca = CsrMatrix::from_dense(&a, 0.0);
            let cb = CsrMatrix::from_dense(&b, 0.0);
            ca.validate().map_err(|e| e.to_string())?;
            if ca.to_dense() != a {
                return Err("CSR round trip broke A".into());
            }
            let got = spgemm(&ca, &cb).map_err(|e| e.to_string())?;
            got.validate().map_err(|e| e.to_string())?;
            let want = a.matmul(&b).unwrap();
            let err = got.to_dense().error_fnorm(&want).unwrap();
            if err > 1e-3 * want.fnorm().max(1.0) {
                return Err(format!("spgemm err {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bf16_quantization_bounds() {
    forall_ok(
        cfg(200),
        |rng: &mut Rng| gen::f32_in(rng, -1e20, 1e20),
        |&x| {
            let q = bf16::quantize(x);
            if x == 0.0 {
                return Ok(());
            }
            let rel = ((q - x) / x).abs();
            if rel > bf16::EPS {
                return Err(format!("x={x} q={q} rel={rel}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_padding_preserves_norm_and_product() {
    forall_ok(
        cfg(15),
        |rng: &mut Rng| {
            (
                gen::usize_in(rng, 1, 70),
                gen::usize_in(rng, 1, 70),
                rng.next_u64(),
            )
        },
        |&(r, c, seed)| {
            let m = Matrix::randn(r, c, seed);
            let p = PaddedMatrix::new(&m, 32);
            if (p.inner.fnorm() - m.fnorm()).abs() > 1e-6 * m.fnorm().max(1.0) {
                return Err("padding changed the F-norm".into());
            }
            if p.crop() != m {
                return Err("crop(pad(m)) != m".into());
            }
            // normmap sum-of-squares equals full norm squared
            let nm = normmap(&p);
            let ss: f64 = nm.data().iter().map(|&x| (x as f64).powi(2)).sum();
            if (ss - m.fnorm().powi(2)).abs() > 1e-5 * m.fnorm().powi(2).max(1.0) {
                return Err("normmap energy mismatch".into());
            }
            Ok(())
        },
    );
}
