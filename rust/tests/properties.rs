//! Cross-module property tests (host-only — no XLA) using the crate's own
//! mini property-testing framework.  These pin the invariants DESIGN.md §7
//! lists.

use std::collections::HashSet;

use cuspamm::config::Balance;
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::proptest::{forall_ok, gen, PropConfig};
use cuspamm::spamm::balance::{Assignment, DeviceView};
use cuspamm::spamm::normmap::normmap;
use cuspamm::spamm::reference::{spamm_flat_host, spamm_recursive};
use cuspamm::spamm::schedule::Schedule;
use cuspamm::spamm::tuner::{tune_tau, TuneParams};
use cuspamm::sparse::formats::{pack_tile, packed_nnz, unpack_tile};
use cuspamm::sparse::spgemm::spgemm;
use cuspamm::sparse::CsrMatrix;
use cuspamm::util::bf16;
use cuspamm::util::prng::Rng;

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        seed: 0xDECAF,
    }
}

#[test]
fn prop_spamm_tau_zero_is_exact_gemm() {
    forall_ok(
        cfg(12),
        |rng: &mut Rng| {
            let n = gen::usize_in(rng, 1, 80);
            let m = gen::usize_in(rng, 1, 80);
            let k = gen::usize_in(rng, 1, 80);
            let seed = rng.next_u64();
            (n, k, m, seed)
        },
        |&(n, k, m, seed)| {
            let a = Matrix::randn(n, k, seed);
            let b = Matrix::randn(k, m, seed ^ 1);
            let got = spamm_flat_host(&a, &b, 0.0, 16).map_err(|e| e.to_string())?;
            let want = a.matmul(&b).map_err(|e| e.to_string())?;
            let err = got.error_fnorm(&want).unwrap();
            let scale = want.fnorm().max(1.0);
            if err / scale > 1e-5 {
                return Err(format!("{n}x{k}x{m}: rel err {}", err / scale));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_error_monotone_in_tau() {
    forall_ok(
        cfg(10),
        |rng: &mut Rng| (gen::pow2_in(rng, 64, 128), rng.next_u64()),
        |&(n, seed)| {
            let a = Matrix::decay_exponential(n, 1.0, 0.5, seed);
            let b = Matrix::decay_exponential(n, 1.0, 0.5, seed ^ 7);
            let exact = a.matmul(&b).unwrap();
            let mut prev = -1.0f64;
            for tau in [0.0f32, 1e-4, 1e-2, 1.0, 100.0] {
                let c = spamm_flat_host(&a, &b, tau, 32).unwrap();
                let e = exact.error_fnorm(&c).unwrap();
                if e < prev - 1e-6 {
                    return Err(format!("n={n} τ={tau}: error dropped {prev} → {e}"));
                }
                prev = e;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_flat_error_at_most_recursive() {
    forall_ok(
        cfg(6),
        |rng: &mut Rng| (rng.next_u64(), gen::f32_in(rng, 1e-4, 1e-1)),
        |&(seed, tau)| {
            let a = Matrix::decay_exponential(64, 1.0, 0.5, seed);
            let b = Matrix::decay_exponential(64, 1.0, 0.5, seed ^ 3);
            let exact = a.matmul(&b).unwrap();
            let ef = exact
                .error_fnorm(&spamm_flat_host(&a, &b, tau, 16).unwrap())
                .unwrap();
            let er = exact
                .error_fnorm(&spamm_recursive(&a, &b, tau, 16).unwrap())
                .unwrap();
            if ef > er + 1e-3 {
                return Err(format!("flat {ef} > recursive {er} at τ={tau}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_schedule_counts_consistent() {
    forall_ok(
        cfg(30),
        |rng: &mut Rng| {
            let tr = gen::usize_in(rng, 1, 12);
            let tk = gen::usize_in(rng, 1, 12);
            let tc = gen::usize_in(rng, 1, 12);
            (tr, tk, tc, rng.next_u64(), gen::f32_in(rng, 0.0, 2.0))
        },
        |&(tr, tk, tc, seed, tau)| {
            let na = {
                let mut m = Matrix::randn(tr, tk, seed);
                for v in m.data_mut() {
                    *v = v.abs();
                }
                m
            };
            let nb = {
                let mut m = Matrix::randn(tk, tc, seed ^ 9);
                for v in m.data_mut() {
                    *v = v.abs();
                }
                m
            };
            let s = Schedule::build(&na, &nb, tau).map_err(|e| e.to_string())?;
            // total = Σ per-tile v == v_matrix sum == products iterator len
            let v_sum: f32 = s.v_matrix().data().iter().sum();
            if v_sum as usize != s.valid_products() {
                return Err("v_matrix sum != valid_products".into());
            }
            let it_count = s
                .products_for_tiles(
                    (0..tr).flat_map(|i| (0..tc).map(move |j| (i, j))),
                )
                .count();
            if it_count != s.valid_products() {
                return Err("iterator count != valid_products".into());
            }
            // every listed k really passes, every omitted k really fails
            for i in 0..tr {
                for j in 0..tc {
                    let ks = s.ks(i, j);
                    let mut idx = 0usize;
                    for k in 0..tk {
                        let pass = na[(i, k)] * nb[(k, j)] >= tau;
                        let listed = idx < ks.len() && ks[idx] == k as u32;
                        if listed {
                            idx += 1;
                        }
                        if pass != listed {
                            return Err(format!("tile ({i},{j}) k={k}: pass={pass} listed={listed}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Distinct operand tiles device `d` needs under assignment `a`.
fn working_set(a: &Assignment, s: &Schedule, d: usize) -> HashSet<(u8, usize, usize)> {
    let mut set = HashSet::new();
    for (i, j) in a.tiles_of(s, d) {
        for &k in s.ks(i, j) {
            set.insert((0u8, i, k as usize));
            set.insert((1u8, k as usize, j));
        }
    }
    set
}

#[test]
fn prop_residency_aware_owns_every_tile_exactly_once() {
    forall_ok(
        cfg(25),
        |rng: &mut Rng| {
            let tr = gen::usize_in(rng, 1, 14);
            let tk = gen::usize_in(rng, 1, 10);
            let tc = gen::usize_in(rng, 1, 14);
            let devices = gen::usize_in(rng, 1, 9);
            (tr, tk, tc, devices, rng.next_u64(), gen::f32_in(rng, 0.0, 1.5))
        },
        |&(tr, tk, tc, devices, seed, tau)| {
            let mut na = Matrix::randn(tr, tk, seed);
            let mut nb = Matrix::randn(tk, tc, seed ^ 17);
            for v in na.data_mut().iter_mut().chain(nb.data_mut()) {
                *v = v.abs();
            }
            let s = Schedule::build(&na, &nb, tau).unwrap();
            let a = Assignment::build_residency_aware(&s, devices, &[], 4096);
            if a.owner.len() != tr * tc {
                return Err("owner map size".into());
            }
            if a.owner.iter().any(|&d| d >= devices) {
                return Err("owner out of range".into());
            }
            let mut seen = vec![false; tr * tc];
            for d in 0..devices {
                for (i, j) in a.tiles_of(&s, d) {
                    let idx = i * tc + j;
                    if seen[idx] {
                        return Err(format!("tile ({i},{j}) owned twice"));
                    }
                    seen[idx] = true;
                }
            }
            if seen.iter().any(|&x| !x) {
                return Err("unowned tile".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_residency_aware_fits_budget_when_every_tile_fits() {
    // When every single output tile's own working set fits the budget
    // and the budget admits the worst-case per-device accumulation
    // (here: total distinct tiles), the greedy fill must keep every
    // device's working set under budget — an always-feasible regime.
    forall_ok(
        cfg(15),
        |rng: &mut Rng| {
            let t = gen::usize_in(rng, 2, 8);
            let devices = gen::usize_in(rng, 2, 4);
            (t, devices, rng.next_u64())
        },
        |&(t, devices, seed)| {
            let mut na = Matrix::randn(t, t, seed);
            let mut nb = Matrix::randn(t, t, seed ^ 23);
            for v in na.data_mut().iter_mut().chain(nb.data_mut()) {
                *v = v.abs();
            }
            let s = Schedule::build(&na, &nb, 0.0).unwrap();
            let tile_bytes = 4096usize;
            // Budget = the whole distinct working set: always feasible.
            let everything = {
                let one = Assignment::build_residency_aware(&s, 1, &[], tile_bytes);
                working_set(&one, &s, 0).len() * tile_bytes
            };
            let views: Vec<DeviceView> = (0..devices)
                .map(|_| DeviceView {
                    budget_bytes: everything,
                    ..DeviceView::default()
                })
                .collect();
            let a = Assignment::build_residency_aware(&s, devices, &views, tile_bytes);
            for d in 0..devices {
                let ws = working_set(&a, &s, d).len() * tile_bytes;
                if ws > everything {
                    return Err(format!("device {d}: ws {ws} > budget {everything}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_residency_aware_never_moves_fully_resident_tiles() {
    forall_ok(
        cfg(12),
        |rng: &mut Rng| {
            let t = gen::usize_in(rng, 2, 10);
            let devices = gen::usize_in(rng, 2, 4);
            let home = gen::usize_in(rng, 0, devices - 1);
            (t, devices, home, rng.next_u64(), gen::f32_in(rng, 0.0, 1.0))
        },
        |&(t, devices, home, seed, tau)| {
            let mut na = Matrix::randn(t, t, seed);
            let mut nb = Matrix::randn(t, t, seed ^ 29);
            for v in na.data_mut().iter_mut().chain(nb.data_mut()) {
                *v = v.abs();
            }
            let s = Schedule::build(&na, &nb, tau).unwrap();
            // Warm `home` with everything a strided partition put there.
            let strided = Assignment::build(&s, devices, Balance::Strided(2));
            let mut views: Vec<DeviceView> =
                (0..devices).map(|_| DeviceView::default()).collect();
            for (i, j) in strided.tiles_of(&s, home) {
                for &k in s.ks(i, j) {
                    views[home].a_resident.insert((i, k as usize));
                    views[home].b_resident.insert((k as usize, j));
                }
            }
            let a = Assignment::build_residency_aware(&s, devices, &views, 4096);
            for (i, j) in strided.tiles_of(&s, home) {
                if s.v(i, j) == 0 {
                    continue; // no work, nothing to keep warm
                }
                if a.owner[i * t + j] != home {
                    return Err(format!(
                        "tile ({i},{j}) moved off device {home} despite full residency"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_residency_aware_imbalance_beats_rowblock_on_decay() {
    forall_ok(
        cfg(8),
        |rng: &mut Rng| {
            (
                gen::pow2_in(rng, 256, 512),
                gen::usize_in(rng, 2, 6),
                rng.next_u64(),
            )
        },
        |&(n, devices, seed)| {
            let m = Matrix::decay_exponential(n, 1.0, 0.55, seed);
            let nm = normmap(&PaddedMatrix::new(&m, 32));
            let s = Schedule::build(&nm, &nm, 5e-1).unwrap();
            let rb = Assignment::build(&s, devices, Balance::RowBlock).imbalance(&s);
            let ra = Assignment::build_residency_aware(&s, devices, &[], 4096).imbalance(&s);
            if ra > rb + 1e-9 {
                return Err(format!(
                    "n={n} devices={devices}: residency-aware {ra:.4} > rowblock {rb:.4}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_assignment_partitions_tiles() {
    forall_ok(
        cfg(30),
        |rng: &mut Rng| {
            let tr = gen::usize_in(rng, 1, 16);
            let tc = gen::usize_in(rng, 1, 16);
            let devices = gen::usize_in(rng, 1, 9);
            let strided = rng.next_f32() < 0.5;
            let stride = gen::usize_in(rng, 1, 6);
            (tr, tc, devices, strided, stride, rng.next_u64())
        },
        |&(tr, tc, devices, strided, stride, seed)| {
            let na = Matrix::randn(tr, 4, seed);
            let nb = Matrix::randn(4, tc, seed ^ 5);
            let s = Schedule::build(&na, &nb, f32::MAX).unwrap();
            let policy = if strided {
                Balance::Strided(stride)
            } else {
                Balance::RowBlock
            };
            let a = Assignment::build(&s, devices, policy);
            let mut seen = vec![0u8; tr * tc];
            for d in 0..devices {
                for (i, j) in a.tiles_of(&s, d) {
                    seen[i * tc + j] += 1;
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err(format!("{policy:?} {devices} devices: not a partition"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tuner_ratio_within_tolerance_or_quantization() {
    forall_ok(
        cfg(15),
        |rng: &mut Rng| {
            (
                gen::usize_in(rng, 2, 10),
                gen::f32_in(rng, 0.05, 0.95) as f64,
                rng.next_u64(),
            )
        },
        |&(bdim, target, seed)| {
            let mut na = Matrix::randn(bdim, bdim, seed);
            let mut nb = Matrix::randn(bdim, bdim, seed ^ 11);
            for v in na.data_mut() {
                *v = v.abs();
            }
            for v in nb.data_mut() {
                *v = v.abs();
            }
            let r = tune_tau(&na, &nb, target, TuneParams { max_iters: 40, tolerance: 0.0 })
                .map_err(|e| e.to_string())?;
            // Reachable ratios are multiples of 1/bdim³; allow quantization.
            let quantum = 1.0 / (bdim * bdim * bdim) as f64;
            if (r.achieved_ratio - target).abs() > quantum + 0.02 {
                return Err(format!(
                    "bdim={bdim} target={target}: achieved {}",
                    r.achieved_ratio
                ));
            }
            // Achieved ratio must be the Schedule's ratio at that τ.
            let s = Schedule::build(&na, &nb, r.tau).unwrap();
            if (s.valid_ratio() - r.achieved_ratio).abs() > 1e-9 {
                return Err("tuner/schedule ratio mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_csr_roundtrip_and_spgemm() {
    forall_ok(
        cfg(20),
        |rng: &mut Rng| {
            (
                gen::usize_in(rng, 1, 30),
                gen::usize_in(rng, 1, 30),
                gen::usize_in(rng, 1, 30),
                gen::f32_in(rng, 0.0, 1.5),
                rng.next_u64(),
            )
        },
        |&(m, k, n, thresh, seed)| {
            let mut a = Matrix::randn(m, k, seed);
            let mut b = Matrix::randn(k, n, seed ^ 13);
            a.truncate(thresh);
            b.truncate(thresh);
            let ca = CsrMatrix::from_dense(&a, 0.0);
            let cb = CsrMatrix::from_dense(&b, 0.0);
            ca.validate().map_err(|e| e.to_string())?;
            if ca.to_dense() != a {
                return Err("CSR round trip broke A".into());
            }
            let got = spgemm(&ca, &cb).map_err(|e| e.to_string())?;
            got.validate().map_err(|e| e.to_string())?;
            let want = a.matmul(&b).unwrap();
            let err = got.to_dense().error_fnorm(&want).unwrap();
            if err > 1e-3 * want.fnorm().max(1.0) {
                return Err(format!("spgemm err {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_unpack_roundtrips_bitwise_at_zero_floor() {
    // The executor stages Sparse/Packed tiles through pack_tile at a
    // zero floor; bitwise inversion (including -0.0) is what makes the
    // threshold-0 conformance guarantee meaningful.
    forall_ok(
        cfg(20),
        |rng: &mut Rng| {
            let l = gen::usize_in(rng, 1, 32);
            (l, gen::f32_in(rng, 0.0, 1.2), rng.next_u64())
        },
        |&(l, trunc, seed)| {
            let mut tile = Matrix::randn(l, l, seed);
            tile.truncate(trunc); // introduces exact +0.0 entries
            let mut data = tile.data().to_vec();
            if !data.is_empty() {
                data[0] = -0.0; // -0.0 must survive a zero-floor pack
            }
            let packed = pack_tile(&data, l, 0.0);
            let kept = data.iter().filter(|x| x.to_bits() != 0).count();
            if packed_nnz(&packed) != kept {
                return Err(format!(
                    "l={l}: packed nnz {} != bit-pattern census {kept}",
                    packed_nnz(&packed)
                ));
            }
            let mut back = vec![f32::NAN; l * l];
            unpack_tile(&packed, l * l, &mut back).map_err(|e| e.to_string())?;
            for (i, (a, b)) in data.iter().zip(&back).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("l={l} elem {i}: {a} != {b} bitwise"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_positive_floor_keeps_exactly_above_floor() {
    // With a positive floor the payload must hold exactly the entries
    // whose magnitude strictly exceeds it — pinned on decay tiles,
    // whose envelope sweeps magnitudes across the floor smoothly.
    forall_ok(
        cfg(15),
        |rng: &mut Rng| (gen::f32_in(rng, 1e-4, 0.5), rng.next_u64()),
        |&(floor, seed)| {
            let m = Matrix::decay_exponential(32, 1.0, 0.2, seed);
            let packed = pack_tile(m.data(), 32, floor);
            let want: Vec<f32> = m
                .data()
                .iter()
                .map(|&x| if x.abs() > floor { x } else { 0.0 })
                .collect();
            let kept = want.iter().filter(|&&x| x != 0.0).count();
            if packed_nnz(&packed) != kept {
                return Err(format!(
                    "floor={floor}: nnz {} != census {kept}",
                    packed_nnz(&packed)
                ));
            }
            let mut back = vec![0.0f32; 32 * 32];
            unpack_tile(&packed, 32 * 32, &mut back).map_err(|e| e.to_string())?;
            if back != want {
                return Err(format!("floor={floor}: floored reconstruction mismatch"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bf16_quantization_bounds() {
    forall_ok(
        cfg(200),
        |rng: &mut Rng| gen::f32_in(rng, -1e20, 1e20),
        |&x| {
            let q = bf16::quantize(x);
            if x == 0.0 {
                return Ok(());
            }
            let rel = ((q - x) / x).abs();
            if rel > bf16::EPS {
                return Err(format!("x={x} q={q} rel={rel}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_padding_preserves_norm_and_product() {
    forall_ok(
        cfg(15),
        |rng: &mut Rng| {
            (
                gen::usize_in(rng, 1, 70),
                gen::usize_in(rng, 1, 70),
                rng.next_u64(),
            )
        },
        |&(r, c, seed)| {
            let m = Matrix::randn(r, c, seed);
            let p = PaddedMatrix::new(&m, 32);
            if (p.inner.fnorm() - m.fnorm()).abs() > 1e-6 * m.fnorm().max(1.0) {
                return Err("padding changed the F-norm".into());
            }
            if p.crop() != m {
                return Err("crop(pad(m)) != m".into());
            }
            // normmap sum-of-squares equals full norm squared
            let nm = normmap(&p);
            let ss: f64 = nm.data().iter().map(|&x| (x as f64).powi(2)).sum();
            if (ss - m.fnorm().powi(2)).abs() > 1e-5 * m.fnorm().powi(2).max(1.0) {
                return Err("normmap energy mismatch".into());
            }
            Ok(())
        },
    );
}
