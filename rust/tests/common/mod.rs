//! Shared helpers for integration tests.

use cuspamm::runtime::ArtifactBundle;

/// Locate the artifact bundle whether tests run from the workspace root or
/// the package dir (honors CUSPAMM_ARTIFACTS).
pub fn bundle() -> ArtifactBundle {
    let candidates = [
        std::env::var("CUSPAMM_ARTIFACTS").unwrap_or_default(),
        "artifacts".to_string(),
        "../artifacts".to_string(),
    ];
    for c in candidates.iter().filter(|c| !c.is_empty()) {
        if std::path::Path::new(c).join("manifest.json").exists() {
            return ArtifactBundle::load(c).expect("manifest parse");
        }
    }
    panic!("artifact bundle not found — run `make artifacts` first");
}
