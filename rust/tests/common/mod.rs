//! Shared helpers for integration tests.

use cuspamm::runtime::ArtifactBundle;

/// Locate the artifact bundle whether tests run from the workspace root
/// or the package dir (honors CUSPAMM_ARTIFACTS).  When no real AOT
/// bundle exists (the python/JAX `make artifacts` step needs a toolchain
/// this environment may not have), a hostsim bundle is synthesized —
/// same manifest schema and artifact grid, interpreted by the offline
/// PJRT simulator — so the whole request path still runs end-to-end.
pub fn bundle() -> ArtifactBundle {
    cuspamm::runtime::hostsim::find_or_test_bundle().expect("artifact bundle")
}
