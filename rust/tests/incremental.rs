//! Integration tests for incremental operand updates
//! ([`SpammSession::update`]): delta uploads, normmap patching, schedule
//! repair, and plan migration.  The headline property: update-then-multiply
//! is bitwise identical to a fresh put of the drifted matrix, across τ,
//! density thresholds, and device counts.

mod common;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, ExprGraph, SpammSession};
use cuspamm::matrix::Matrix;
use cuspamm::util::prng::Rng;

use common::bundle;

/// Tile edge of the test bundle.
const L: usize = 32;

fn session(cfg: SpammConfig) -> SpammSession {
    SpammSession::new(&bundle(), cfg).unwrap()
}

/// One `L×L` block of small random drift per changed tile, concatenated
/// in `changed` order — the payload layout `update` expects.
fn drift_payload(changed: &[(usize, usize)], seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..changed.len() * L * L)
        .map(|_| 0.05 * rng.range_f32(-1.0, 1.0))
        .collect()
}

/// Apply the same payload to a host-side mirror of the operand, so a
/// fresh `put` of the mirror sees exactly what `update` produced.
fn patch_host(m: &mut Matrix, changed: &[(usize, usize)], data: &[f32]) {
    let n = m.cols();
    for (k, &(ti, tj)) in changed.iter().enumerate() {
        let block = &data[k * L * L..(k + 1) * L * L];
        for r in 0..L {
            m.data_mut()[(ti * L + r) * n + tj * L..][..L]
                .copy_from_slice(&block[r * L..(r + 1) * L]);
        }
    }
}

/// An `n×n` matrix whose diagonal tiles are dense and whose off-diagonal
/// tiles hold a single nonzero — under a 0.25 density threshold the
/// off-diagonal tiles route through the packed (COO) tile path.
fn block_sparse(n: usize, seed: u64) -> Matrix {
    let mut m = Matrix::zeros(n, n);
    let side = n / L;
    let mut rng = Rng::new(seed);
    for ti in 0..side {
        for tj in 0..side {
            if ti == tj {
                for r in 0..L {
                    for c in 0..L {
                        m.data_mut()[(ti * L + r) * n + tj * L + c] = rng.range_f32(-1.0, 1.0);
                    }
                }
            } else {
                let (r, c) = (rng.below(L), rng.below(L));
                m.data_mut()[(ti * L + r) * n + tj * L + c] = rng.range_f32(0.5, 1.0);
            }
        }
    }
    m
}

/// The headline property: for every (devices, τ, density-threshold)
/// combination, updating three tiles of a prepared operand and re-running
/// the migrated plan produces bits identical to a fresh session that
/// `put` the drifted matrix and built everything cold.
#[test]
fn update_matches_fresh_put_across_tau_threshold_devices() {
    let n = 4 * L;
    let changed = [(0usize, 1usize), (2, 2), (3, 0)];
    for devices in [1usize, 2] {
        for tau in [0.0f32, 1e-3] {
            for dt in [0.0f32, 0.25] {
                let cfg = SpammConfig {
                    devices,
                    density_threshold: dt,
                    ..SpammConfig::default()
                };
                let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 17);
                let s = session(cfg.clone());
                let aid = s.put(&host).unwrap();
                let plan = s.prepare(aid, aid, Approx::Tau(tau)).unwrap();
                let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

                let data = drift_payload(&changed, 40 + devices as u64);
                patch_host(&mut host, &changed, &data);
                let rep = s.update(aid, &changed, &data).unwrap();
                assert_eq!(rep.tiles_changed, 3, "{devices}d τ={tau} dt={dt}");
                assert!(rep.norm_patched, "{devices}d τ={tau} dt={dt}: {rep:?}");
                assert_eq!(rep.norm_tiles_patched, 3, "{devices}d τ={tau} dt={dt}");
                assert!(
                    rep.schedules_repaired >= 1,
                    "{devices}d τ={tau} dt={dt}: the cached schedule must be \
                     repaired, not rebuilt: {rep:?}"
                );
                assert_eq!(rep.plans_migrated, 1, "{devices}d τ={tau} dt={dt}");
                let warm = s.wait(s.submit(plan).unwrap()).unwrap();
                assert_eq!(
                    warm.stats.schedule_cache_misses, 0,
                    "{devices}d τ={tau} dt={dt}: migrated plan must reuse the \
                     repaired schedule"
                );

                let f = session(cfg);
                let fid = f.put(&host).unwrap();
                let fplan = f.prepare(fid, fid, Approx::Tau(tau)).unwrap();
                let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
                assert_eq!(
                    warm.c.data(),
                    fresh.c.data(),
                    "{devices}d τ={tau} dt={dt}: update-then-multiply must be \
                     bitwise identical to a fresh put of the drifted matrix"
                );
            }
        }
    }
}

/// Updates stay correct when the device pool is too small to hold the
/// operand: evicted tiles simply aren't patched (they re-upload on next
/// use), and only still-resident changed tiles cost transfer.
#[test]
fn update_under_pool_eviction_pressure_stays_correct() {
    let n = 4 * L;
    let tile_bytes = L * L * 4;
    let cfg = SpammConfig {
        device_mem_budget: 8 * tile_bytes, // half of one 16-tile operand
        ..SpammConfig::default()
    };
    let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 23);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(1e-4)).unwrap();
    let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

    let changed = [(1usize, 1usize), (0, 3), (2, 0), (3, 3)];
    let data = drift_payload(&changed, 9);
    patch_host(&mut host, &changed, &data);
    let rep = s.update(aid, &changed, &data).unwrap();
    assert!(
        rep.uploaded_tiles <= changed.len(),
        "only still-resident changed tiles may upload: {rep:?}"
    );
    let warm = s.wait(s.submit(plan).unwrap()).unwrap();

    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fplan = f.prepare(fid, fid, Approx::Tau(1e-4)).unwrap();
    let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
    assert_eq!(warm.c.data(), fresh.c.data());
}

/// Regression: a changed tile's cached *packed* (COO) payload is dropped,
/// never re-keyed to the new fingerprint — a stale packed variant would
/// silently feed the sparse tile path pre-update bytes.
#[test]
fn stale_packed_payloads_are_dropped_on_update() {
    let n = 4 * L;
    let cfg = SpammConfig {
        density_threshold: 0.25,
        ..SpammConfig::default()
    };
    let mut host = block_sparse(n, 5);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(0.0)).unwrap();
    let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

    // Move the off-diagonal tile (0,2)'s nonzero somewhere else: same
    // density class (still packed-eligible), different content.
    let mut data = [0.0f32; L * L];
    data[3 * L + 7] = 0.9;
    patch_host(&mut host, &[(0, 2)], &data);
    let rep = s.update(aid, &[(0, 2)], &data).unwrap();
    assert!(
        rep.dropped_stale >= 1,
        "the changed tile's resident packed payload must be dropped: {rep:?}"
    );
    let warm = s.wait(s.submit(plan).unwrap()).unwrap();

    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fplan = f.prepare(fid, fid, Approx::Tau(0.0)).unwrap();
    let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
    assert_eq!(
        warm.c.data(),
        fresh.c.data(),
        "a stale packed payload surviving the update would corrupt these bits"
    );
}

/// Malformed updates are rejected atomically: the operand, its caches,
/// and its prepared plans are left exactly as they were.
#[test]
fn update_validates_inputs_and_leaves_state_intact() {
    let n = 4 * L;
    let host = Matrix::decay_algebraic(n, 0.1, 0.1, 31);
    let s = session(SpammConfig::default());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(1e-4)).unwrap();
    let cold = s.wait(s.submit(plan).unwrap()).unwrap();

    // Payload length must be exactly changed.len() tiles.
    assert!(s.update(aid, &[(0, 0)], &[0.0; 7]).is_err());
    assert!(s.update(aid, &[(0, 0)], &[0.0; 2 * L * L]).is_err());
    // Tile coordinates must lie inside the padded grid.
    assert!(s.update(aid, &[(4, 0)], &[0.0; L * L]).is_err());
    assert!(s.update(aid, &[(0, 9)], &[0.0; L * L]).is_err());

    // Nothing changed: the plan still runs and reproduces the cold bits.
    let warm = s.wait(s.submit(plan).unwrap()).unwrap();
    assert_eq!(warm.c.data(), cold.c.data());

    // Duplicate coordinates collapse to one logical tile change.
    let dup = [(1usize, 1usize), (1, 1)];
    let data = drift_payload(&dup, 3);
    let rep = s.update(aid, &dup, &data).unwrap();
    assert_eq!(rep.tiles_changed, 1);
}

/// Expression plans referencing an updated operand migrate too: the next
/// graph submit runs against the new bits and matches a cold rebuild.
#[test]
fn expr_plans_survive_updates_of_their_inputs() {
    let n = 4 * L;
    let tau = 1e-4f32;
    let cfg = SpammConfig::default();
    let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 29);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let sq = g.spamm(leaf, leaf, Approx::Tau(tau));
    let cube = g.spamm(sq, leaf, Approx::Tau(tau));
    g.output(cube);
    let ep = s.prepare_expr(&g, &[aid]).unwrap();
    let _cold = s.wait(s.submit_expr(ep).unwrap()).unwrap();

    let changed = [(1usize, 2usize), (3, 1)];
    let data = drift_payload(&changed, 77);
    patch_host(&mut host, &changed, &data);
    let rep = s.update(aid, &changed, &data).unwrap();
    assert_eq!(rep.expr_plans_migrated, 1, "{rep:?}");
    let warm = s.wait(s.submit_expr(ep).unwrap()).unwrap();

    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fep = f.prepare_expr(&g, &[fid]).unwrap();
    let fresh = f.wait(f.submit_expr(fep).unwrap()).unwrap();
    assert_eq!(
        warm.c.data(),
        fresh.c.data(),
        "a migrated expression plan must reproduce the cold rebuild bitwise"
    );
}
