//! Integration tests for incremental operand updates
//! ([`SpammSession::update`]): delta uploads, normmap patching, schedule
//! repair, and plan migration.  The headline property: update-then-multiply
//! is bitwise identical to a fresh put of the drifted matrix, across τ,
//! density thresholds, and device counts.

mod common;

use cuspamm::audit::schedule_structural_diff;
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, ExprGraph, PlanId, SpammSession};
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::spamm::normmap::normmap_with_density;
use cuspamm::spamm::Schedule;
use cuspamm::util::prng::Rng;

use common::bundle;

/// Tile edge of the test bundle.
const L: usize = 32;

/// The repair ≡ rebuild contract: after any delta update, the schedule a
/// migrated plan holds (repaired in place by `Schedule::repair`) must be
/// structurally identical — same surviving products, same strategy tags —
/// to one built from scratch over the drifted operand.  The comparison
/// runs through the static auditor's `schedule_structural_diff`, which
/// never calls the builder or the repairer itself.
fn assert_repair_matches_rebuild(s: &SpammSession, plan: PlanId, host: &Matrix, ctx: &str) {
    let (sched, tau, dt) = s.plan_schedule(plan).unwrap();
    let nm = normmap_with_density(&PaddedMatrix::new(host, L));
    let fresh = Schedule::build_adaptive(&nm, &nm, tau, dt).unwrap();
    let diff = schedule_structural_diff(&sched, &fresh);
    assert!(
        diff.ok(),
        "{ctx}: repaired schedule diverged from a fresh rebuild: {:?}",
        diff.violations
    );
}

fn session(cfg: SpammConfig) -> SpammSession {
    SpammSession::new(&bundle(), cfg).unwrap()
}

/// One `L×L` block of small random drift per changed tile, concatenated
/// in `changed` order — the payload layout `update` expects.
fn drift_payload(changed: &[(usize, usize)], seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..changed.len() * L * L)
        .map(|_| 0.05 * rng.range_f32(-1.0, 1.0))
        .collect()
}

/// Apply the same payload to a host-side mirror of the operand, so a
/// fresh `put` of the mirror sees exactly what `update` produced.
fn patch_host(m: &mut Matrix, changed: &[(usize, usize)], data: &[f32]) {
    let n = m.cols();
    for (k, &(ti, tj)) in changed.iter().enumerate() {
        let block = &data[k * L * L..(k + 1) * L * L];
        for r in 0..L {
            m.data_mut()[(ti * L + r) * n + tj * L..][..L]
                .copy_from_slice(&block[r * L..(r + 1) * L]);
        }
    }
}

/// An `n×n` matrix whose diagonal tiles are dense and whose off-diagonal
/// tiles hold a single nonzero — under a 0.25 density threshold the
/// off-diagonal tiles route through the packed (COO) tile path.
fn block_sparse(n: usize, seed: u64) -> Matrix {
    let mut m = Matrix::zeros(n, n);
    let side = n / L;
    let mut rng = Rng::new(seed);
    for ti in 0..side {
        for tj in 0..side {
            if ti == tj {
                for r in 0..L {
                    for c in 0..L {
                        m.data_mut()[(ti * L + r) * n + tj * L + c] = rng.range_f32(-1.0, 1.0);
                    }
                }
            } else {
                let (r, c) = (rng.below(L), rng.below(L));
                m.data_mut()[(ti * L + r) * n + tj * L + c] = rng.range_f32(0.5, 1.0);
            }
        }
    }
    m
}

/// The headline property: for every (devices, τ, density-threshold)
/// combination, updating three tiles of a prepared operand and re-running
/// the migrated plan produces bits identical to a fresh session that
/// `put` the drifted matrix and built everything cold.
#[test]
fn update_matches_fresh_put_across_tau_threshold_devices() {
    let n = 4 * L;
    let changed = [(0usize, 1usize), (2, 2), (3, 0)];
    for devices in [1usize, 2] {
        for tau in [0.0f32, 1e-3] {
            for dt in [0.0f32, 0.25] {
                let cfg = SpammConfig {
                    devices,
                    density_threshold: dt,
                    ..SpammConfig::default()
                };
                let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 17);
                let s = session(cfg.clone());
                let aid = s.put(&host).unwrap();
                let plan = s.prepare(aid, aid, Approx::Tau(tau)).unwrap();
                let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

                let data = drift_payload(&changed, 40 + devices as u64);
                patch_host(&mut host, &changed, &data);
                let rep = s.update(aid, &changed, &data).unwrap();
                assert_eq!(rep.tiles_changed, 3, "{devices}d τ={tau} dt={dt}");
                assert!(rep.norm_patched, "{devices}d τ={tau} dt={dt}: {rep:?}");
                assert_eq!(rep.norm_tiles_patched, 3, "{devices}d τ={tau} dt={dt}");
                assert!(
                    rep.schedules_repaired >= 1,
                    "{devices}d τ={tau} dt={dt}: the cached schedule must be \
                     repaired, not rebuilt: {rep:?}"
                );
                assert_eq!(rep.plans_migrated, 1, "{devices}d τ={tau} dt={dt}");
                assert_repair_matches_rebuild(
                    &s,
                    plan,
                    &host,
                    &format!("{devices}d τ={tau} dt={dt}"),
                );
                let warm = s.wait(s.submit(plan).unwrap()).unwrap();
                assert_eq!(
                    warm.stats.schedule_cache_misses, 0,
                    "{devices}d τ={tau} dt={dt}: migrated plan must reuse the \
                     repaired schedule"
                );

                let f = session(cfg);
                let fid = f.put(&host).unwrap();
                let fplan = f.prepare(fid, fid, Approx::Tau(tau)).unwrap();
                let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
                assert_eq!(
                    warm.c.data(),
                    fresh.c.data(),
                    "{devices}d τ={tau} dt={dt}: update-then-multiply must be \
                     bitwise identical to a fresh put of the drifted matrix"
                );
            }
        }
    }
}

/// Updates stay correct when the device pool is too small to hold the
/// operand: evicted tiles simply aren't patched (they re-upload on next
/// use), and only still-resident changed tiles cost transfer.
#[test]
fn update_under_pool_eviction_pressure_stays_correct() {
    let n = 4 * L;
    let tile_bytes = L * L * 4;
    let cfg = SpammConfig {
        device_mem_budget: 8 * tile_bytes, // half of one 16-tile operand
        ..SpammConfig::default()
    };
    let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 23);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(1e-4)).unwrap();
    let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

    let changed = [(1usize, 1usize), (0, 3), (2, 0), (3, 3)];
    let data = drift_payload(&changed, 9);
    patch_host(&mut host, &changed, &data);
    let rep = s.update(aid, &changed, &data).unwrap();
    assert!(
        rep.uploaded_tiles <= changed.len(),
        "only still-resident changed tiles may upload: {rep:?}"
    );
    let warm = s.wait(s.submit(plan).unwrap()).unwrap();

    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fplan = f.prepare(fid, fid, Approx::Tau(1e-4)).unwrap();
    let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
    assert_eq!(warm.c.data(), fresh.c.data());
}

/// Regression: a changed tile's cached *packed* (COO) payload is dropped,
/// never re-keyed to the new fingerprint — a stale packed variant would
/// silently feed the sparse tile path pre-update bytes.
#[test]
fn stale_packed_payloads_are_dropped_on_update() {
    let n = 4 * L;
    let cfg = SpammConfig {
        density_threshold: 0.25,
        ..SpammConfig::default()
    };
    let mut host = block_sparse(n, 5);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(0.0)).unwrap();
    let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

    // Move the off-diagonal tile (0,2)'s nonzero somewhere else: same
    // density class (still packed-eligible), different content.
    let mut data = [0.0f32; L * L];
    data[3 * L + 7] = 0.9;
    patch_host(&mut host, &[(0, 2)], &data);
    let rep = s.update(aid, &[(0, 2)], &data).unwrap();
    assert!(
        rep.dropped_stale >= 1,
        "the changed tile's resident packed payload must be dropped: {rep:?}"
    );
    assert_repair_matches_rebuild(&s, plan, &host, "packed drift");
    let warm = s.wait(s.submit(plan).unwrap()).unwrap();

    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fplan = f.prepare(fid, fid, Approx::Tau(0.0)).unwrap();
    let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
    assert_eq!(
        warm.c.data(),
        fresh.c.data(),
        "a stale packed payload surviving the update would corrupt these bits"
    );
}

/// Malformed updates are rejected atomically: the operand, its caches,
/// and its prepared plans are left exactly as they were.
#[test]
fn update_validates_inputs_and_leaves_state_intact() {
    let n = 4 * L;
    let host = Matrix::decay_algebraic(n, 0.1, 0.1, 31);
    let s = session(SpammConfig::default());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(1e-4)).unwrap();
    let cold = s.wait(s.submit(plan).unwrap()).unwrap();

    // Payload length must be exactly changed.len() tiles.
    assert!(s.update(aid, &[(0, 0)], &[0.0; 7]).is_err());
    assert!(s.update(aid, &[(0, 0)], &[0.0; 2 * L * L]).is_err());
    // Tile coordinates must lie inside the padded grid.
    assert!(s.update(aid, &[(4, 0)], &[0.0; L * L]).is_err());
    assert!(s.update(aid, &[(0, 9)], &[0.0; L * L]).is_err());

    // Nothing changed: the plan still runs and reproduces the cold bits.
    let warm = s.wait(s.submit(plan).unwrap()).unwrap();
    assert_eq!(warm.c.data(), cold.c.data());

    // Duplicate coordinates collapse to one logical tile change.
    let dup = [(1usize, 1usize), (1, 1)];
    let data = drift_payload(&dup, 3);
    let rep = s.update(aid, &dup, &data).unwrap();
    assert_eq!(rep.tiles_changed, 1);
}

/// Deferred deltas to one operand coalesce: the union of changed tiles
/// lands as a single patch (one fingerprint derivation, one norm patch,
/// one repair sweep), overlapping tiles keep the last payload, and the
/// result is bitwise identical to a fresh put of the merged content.
#[test]
fn deferred_updates_coalesce_into_one_patch() {
    let n = 4 * L;
    let tau = 1e-4f32;
    let cfg = SpammConfig::default();
    let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 51);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(tau)).unwrap();
    let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

    // Two deferred deltas sharing tile (2,2): the second payload must
    // win, and the pending set must be the 3-tile union.
    let first = [(0usize, 1usize), (2, 2)];
    let data1 = drift_payload(&first, 60);
    assert_eq!(s.update_deferred(aid, &first, &data1).unwrap(), 2);
    let second = [(2usize, 2usize), (3, 0)];
    let data2 = drift_payload(&second, 61);
    assert_eq!(s.update_deferred(aid, &second, &data2).unwrap(), 3);
    // Host mirror in call order — the overlap resolves last-writer-wins.
    patch_host(&mut host, &first, &data1);
    patch_host(&mut host, &second, &data2);

    let flushed = s.flush_updates().unwrap();
    assert_eq!(flushed.len(), 1, "one operand pending → one merged patch");
    let (id, rep) = &flushed[0];
    assert_eq!(*id, aid);
    assert_eq!(rep.tiles_changed, 3, "union of both deltas: {rep:?}");
    assert_eq!(rep.norm_tiles_patched, 3, "one patch, not one per call");
    assert!(rep.norm_patched, "{rep:?}");
    // Nothing left pending: a second flush is a no-op.
    assert!(s.flush_updates().unwrap().is_empty());

    let warm = s.wait(s.submit(plan).unwrap()).unwrap();
    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fplan = f.prepare(fid, fid, Approx::Tau(tau)).unwrap();
    let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
    assert_eq!(
        warm.c.data(),
        fresh.c.data(),
        "coalesced patch must be bitwise identical to a fresh put of the \
         merged content (last writer winning the overlapped tile)"
    );
}

/// Submits flush implicitly: a job never runs against half-flushed
/// operands, and the deferred content is visible to it.
#[test]
fn submit_flushes_deferred_updates() {
    let n = 4 * L;
    let tau = 1e-4f32;
    let cfg = SpammConfig::default();
    let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 53);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let plan = s.prepare(aid, aid, Approx::Tau(tau)).unwrap();
    let _cold = s.wait(s.submit(plan).unwrap()).unwrap();

    let changed = [(1usize, 3usize), (2, 0)];
    let data = drift_payload(&changed, 62);
    patch_host(&mut host, &changed, &data);
    s.update_deferred(aid, &changed, &data).unwrap();
    // No explicit flush: submit must apply the pending patch first.
    let warm = s.wait(s.submit(plan).unwrap()).unwrap();
    assert!(
        s.flush_updates().unwrap().is_empty(),
        "submit must have drained the pending patch"
    );

    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fplan = f.prepare(fid, fid, Approx::Tau(tau)).unwrap();
    let fresh = f.wait(f.submit(fplan).unwrap()).unwrap();
    assert_eq!(
        warm.c.data(),
        fresh.c.data(),
        "the submitted job must see the deferred delta"
    );
}

/// Coalescing is transparent: deferring a batch of deltas and flushing
/// once produces the same bits as applying each delta with its own
/// `update` call — and flushes across operands apply in id order, one
/// merged patch each.
#[test]
fn coalesced_flush_matches_sequential_updates() {
    let n = 4 * L;
    let tau = 1e-3f32;
    let cfg = SpammConfig::default();
    let host_a = Matrix::decay_algebraic(n, 0.1, 0.1, 55);
    let host_b = Matrix::decay_algebraic(n, 0.1, 0.1, 56);
    let d1 = [(0usize, 0usize), (1, 2)];
    let d2 = [(3usize, 3usize)];
    let p1 = drift_payload(&d1, 63);
    let p2 = drift_payload(&d2, 64);

    // Sequential: one update call per delta, per operand.
    let seq = session(cfg.clone());
    let sa = seq.put(&host_a).unwrap();
    let sb = seq.put(&host_b).unwrap();
    let splan = seq.prepare(sa, sb, Approx::Tau(tau)).unwrap();
    let _ = seq.wait(seq.submit(splan).unwrap()).unwrap();
    seq.update(sa, &d1, &p1).unwrap();
    seq.update(sa, &d2, &p2).unwrap();
    seq.update(sb, &d2, &p2).unwrap();
    let s_done = seq.wait(seq.submit(splan).unwrap()).unwrap();

    // Coalesced: defer everything, flush once.
    let co = session(cfg);
    let ca = co.put(&host_a).unwrap();
    let cb = co.put(&host_b).unwrap();
    let cplan = co.prepare(ca, cb, Approx::Tau(tau)).unwrap();
    let _ = co.wait(co.submit(cplan).unwrap()).unwrap();
    co.update_deferred(ca, &d1, &p1).unwrap();
    co.update_deferred(ca, &d2, &p2).unwrap();
    co.update_deferred(cb, &d2, &p2).unwrap();
    let flushed = co.flush_updates().unwrap();
    assert_eq!(flushed.len(), 2, "two operands pending → two merged patches");
    assert_eq!(
        (flushed[0].0, flushed[1].0),
        (ca, cb),
        "flush applies in operand-id order"
    );
    assert_eq!(flushed[0].1.tiles_changed, 3, "operand a: 3-tile union");
    assert_eq!(flushed[1].1.tiles_changed, 1, "operand b: single tile");
    let c_done = co.wait(co.submit(cplan).unwrap()).unwrap();

    assert_eq!(
        c_done.c.data(),
        s_done.c.data(),
        "one coalesced patch must reproduce the sequential updates bitwise"
    );
}

/// Deferred-path validation mirrors `update`: malformed deltas are
/// rejected before anything is buffered, earlier valid deferrals
/// survive, and an empty `update` is a no-op receipt.
#[test]
fn update_deferred_validates_and_preserves_pending() {
    let n = 4 * L;
    let host = Matrix::decay_algebraic(n, 0.1, 0.1, 57);
    let s = session(SpammConfig::default());
    let aid = s.put(&host).unwrap();

    let good = [(1usize, 1usize)];
    let payload = drift_payload(&good, 65);
    assert_eq!(s.update_deferred(aid, &good, &payload).unwrap(), 1);
    // Wrong payload length and out-of-grid coordinates: rejected without
    // disturbing the already-pending tile.
    assert!(s.update_deferred(aid, &[(0, 0)], &[0.0; 7]).is_err());
    assert!(s.update_deferred(aid, &[(9, 0)], &[0.0; L * L]).is_err());
    let flushed = s.flush_updates().unwrap();
    assert_eq!(flushed.len(), 1);
    assert_eq!(flushed[0].1.tiles_changed, 1);

    // An empty delta with nothing pending: a default (no-op) receipt.
    let rep = s.update(aid, &[], &[]).unwrap();
    assert_eq!(rep.tiles_changed, 0);
    assert!(s.flush_updates().unwrap().is_empty());
}

/// Expression plans referencing an updated operand migrate too: the next
/// graph submit runs against the new bits and matches a cold rebuild.
#[test]
fn expr_plans_survive_updates_of_their_inputs() {
    let n = 4 * L;
    let tau = 1e-4f32;
    let cfg = SpammConfig::default();
    let mut host = Matrix::decay_algebraic(n, 0.1, 0.1, 29);
    let s = session(cfg.clone());
    let aid = s.put(&host).unwrap();
    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let sq = g.spamm(leaf, leaf, Approx::Tau(tau));
    let cube = g.spamm(sq, leaf, Approx::Tau(tau));
    g.output(cube);
    let ep = s.prepare_expr(&g, &[aid]).unwrap();
    let _cold = s.wait(s.submit_expr(ep).unwrap()).unwrap();

    let changed = [(1usize, 2usize), (3, 1)];
    let data = drift_payload(&changed, 77);
    patch_host(&mut host, &changed, &data);
    let rep = s.update(aid, &changed, &data).unwrap();
    assert_eq!(rep.expr_plans_migrated, 1, "{rep:?}");
    let warm = s.wait(s.submit_expr(ep).unwrap()).unwrap();

    let f = session(cfg);
    let fid = f.put(&host).unwrap();
    let fep = f.prepare_expr(&g, &[fid]).unwrap();
    let fresh = f.wait(f.submit_expr(fep).unwrap()).unwrap();
    assert_eq!(
        warm.c.data(),
        fresh.c.data(),
        "a migrated expression plan must reproduce the cold rebuild bitwise"
    );
}
