//! Mutation tests for the cross-layer invariant auditor, driven through
//! the public API: a hand-engineered schedule is corrupted one invariant
//! at a time and the auditor must name each violation with the right
//! kind; a real warm store gets on-disk corruption; and real session
//! workloads must audit clean end-to-end.  (Expression-plan and
//! pool-counter mutations need crate-private access and live in
//! `src/audit/tests.rs`.)

mod common;

use std::fs;
use std::path::{Path, PathBuf};

use cuspamm::audit::{
    audit_assignment, audit_pool, audit_schedule, audit_store, AuditKind, AuditReport,
};
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, ExprGraph, SpammSession};
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::runtime::residency::ResidencyPool;
use cuspamm::spamm::balance::Assignment;
use cuspamm::spamm::cache::{fingerprint, Fingerprint};
use cuspamm::spamm::normmap::{normmap_with_density, NormMap};
use cuspamm::spamm::{Schedule, TileStrategy};
use cuspamm::store::WarmStore;

use common::bundle;

const L: usize = 32;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cuspamm_audit_it_{}_{}", tag, std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A synthetic 2×2-output grid with contraction depth 3, engineered so
/// every culling/strategy/packed case appears at τ = 1, threshold 0.5:
///   slot (0,0): ks [0]    [Dense]
///   slot (0,1): ks [0,1]  [Packed, Packed]
///   slot (1,0): ks [0]    [Dense]
///   slot (1,1): ks [0,1]  [Dense, Dense]
fn synthetic() -> (NormMap, NormMap, Schedule) {
    let na = NormMap {
        norms: Matrix::from_vec(2, 3, vec![2.0, 1.0, 0.1, 1.0, 2.0, 0.5]).unwrap(),
        density: Matrix::from_vec(2, 3, vec![0.1, 0.1, 1.0, 1.0, 1.0, 1.0]).unwrap(),
    };
    let nb = NormMap {
        norms: Matrix::from_vec(3, 2, vec![2.0, 1.0, 0.1, 2.0, 1.0, 1.0]).unwrap(),
        density: Matrix::from_vec(3, 2, vec![1.0, 0.1, 1.0, 0.1, 1.0, 1.0]).unwrap(),
    };
    let s = Schedule::build_adaptive(&na, &nb, 1.0, 0.5).unwrap();
    (na, nb, s)
}

fn expect_kind(r: &AuditReport, kind: AuditKind) {
    assert!(
        r.find(kind).is_some(),
        "expected a {kind:?} violation, got: {:?}",
        r.violations
    );
}

#[test]
fn pristine_synthetic_schedule_audits_clean() {
    let (na, nb, s) = synthetic();
    // Sanity: the engineered grid really exercises every case.
    assert_eq!(s.valid_k, vec![vec![0], vec![0, 1], vec![0], vec![0, 1]]);
    assert_eq!(s.strategies[1], vec![TileStrategy::Packed, TileStrategy::Packed]);
    assert_eq!(s.strategies[3], vec![TileStrategy::Dense, TileStrategy::Dense]);
    let r = audit_schedule(&na, &nb, 1.0, 0.5, &s);
    assert!(r.ok(), "pristine schedule flagged: {:?}", r.violations);
    assert!(r.checks > 0);
}

#[test]
fn unculled_below_tau_product_is_spurious() {
    let (na, nb, mut s) = synthetic();
    // k=1 in slot (0,0) has bound 1·0.1 = 0.1 < τ = 1.
    s.valid_k[0].push(1);
    s.strategies[0].push(TileStrategy::Dense);
    expect_kind(
        &audit_schedule(&na, &nb, 1.0, 0.5, &s),
        AuditKind::SpuriousProduct,
    );
}

#[test]
fn dropped_surviving_product_is_missed() {
    let (na, nb, mut s) = synthetic();
    // k=0 in slot (1,1) has bound 1·1 = 1 ≥ τ — culling is inclusive.
    s.valid_k[3].remove(0);
    s.strategies[3].remove(0);
    expect_kind(
        &audit_schedule(&na, &nb, 1.0, 0.5, &s),
        AuditKind::MissedProduct,
    );
}

#[test]
fn descending_k_list_is_malformed() {
    let (na, nb, mut s) = synthetic();
    s.valid_k[3].swap(0, 1);
    expect_kind(
        &audit_schedule(&na, &nb, 1.0, 0.5, &s),
        AuditKind::MalformedKList,
    );
}

#[test]
fn tag_length_disagreement_is_malformed() {
    let (na, nb, mut s) = synthetic();
    s.strategies[1].pop();
    expect_kind(
        &audit_schedule(&na, &nb, 1.0, 0.5, &s),
        AuditKind::MalformedKList,
    );
}

#[test]
fn dense_product_mistagged_sparse_is_a_strategy_mismatch() {
    let (na, nb, mut s) = synthetic();
    // Slot (1,0)'s operand tiles are census-dense; neither the expected
    // nor the forged tag is Packed, so this is a plain mismatch.
    s.strategies[2][0] = TileStrategy::Sparse;
    expect_kind(
        &audit_schedule(&na, &nb, 1.0, 0.5, &s),
        AuditKind::StrategyMismatch,
    );
}

#[test]
fn split_packed_run_is_reported_as_broken() {
    let (na, nb, mut s) = synthetic();
    // De-pack the second element of slot (0,1)'s 2-run: the survivor set
    // is untouched, only the consecutive-run property breaks.
    s.strategies[1][1] = TileStrategy::Dense;
    expect_kind(
        &audit_schedule(&na, &nb, 1.0, 0.5, &s),
        AuditKind::BrokenPackedRun,
    );
}

#[test]
fn ownership_corruptions_are_detected() {
    let (_, _, s) = synthetic();
    let asg = Assignment::build(&s, 2, cuspamm::config::Balance::RowBlock);
    assert!(audit_assignment(&s, &asg).ok());

    let mut bad = asg.clone();
    bad.owner.pop();
    expect_kind(&audit_assignment(&s, &bad), AuditKind::OwnerMapMismatch);

    let mut bad = asg.clone();
    bad.owner[0] = 5;
    expect_kind(&audit_assignment(&s, &bad), AuditKind::OwnerOutOfRange);
}

/// The auditor's independent reimplementation must agree with the real
/// builder on real matrices across the (τ, density-threshold) plane.
#[test]
fn real_schedules_audit_clean_across_tau_and_threshold() {
    let m = Matrix::decay_algebraic(4 * L, 0.1, 0.1, 11);
    let nm = normmap_with_density(&PaddedMatrix::new(&m, L));
    for tau in [0.0f32, 1e-4, 1e-2] {
        for dt in [0.0f32, 0.25, 1.0] {
            let s = Schedule::build_adaptive(&nm, &nm, tau, dt).unwrap();
            let r = audit_schedule(&nm, &nm, tau, dt, &s);
            assert!(r.ok(), "τ={tau} dt={dt}: {:?}", r.violations);
        }
    }
}

#[test]
fn orphan_pin_is_detected_through_the_public_api() {
    let pool = ResidencyPool::new(1 << 20);
    pool.pin_operand(Fingerprint(1, 2));
    let live = std::collections::HashSet::new();
    expect_kind(&audit_pool(&pool, Some(&live)), AuditKind::OrphanPin);
    let live: std::collections::HashSet<Fingerprint> = [Fingerprint(1, 2)].into_iter().collect();
    assert!(audit_pool(&pool, Some(&live)).ok());
}

/// One persisted normmap per corruption mode; `audit_store` must name
/// the exact failure kind, and `verify(heal)` — which routes through the
/// same auditor — must evict the bad entry and leave the store clean.
fn seeded_store(dir: &Path, seed: u64) -> WarmStore {
    let store = WarmStore::open(dir).unwrap();
    let m = Matrix::randn(2 * L, 2 * L, seed);
    let p = PaddedMatrix::new(&m, L);
    store.save_normmap(fingerprint(&p), &normmap_with_density(&p));
    store
}

fn object_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    for ent in fs::read_dir(dir.join("objects")).unwrap() {
        let p = ent.unwrap().path();
        if p.extension().and_then(|e| e.to_str()) == Some("bin") {
            out.push(p);
        }
    }
    out
}

#[test]
fn store_bit_flip_is_a_checksum_violation() {
    let dir = tmp_dir("flip");
    let store = seeded_store(&dir, 3);
    assert!(audit_store(&store).ok());
    for p in object_files(&dir) {
        let mut bytes = fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        fs::write(&p, &bytes).unwrap();
    }
    expect_kind(&audit_store(&store), AuditKind::StoreChecksum);
    store.verify(true).unwrap();
    assert!(audit_store(&store).ok(), "heal must leave the store clean");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn store_truncation_is_a_size_violation() {
    let dir = tmp_dir("trunc");
    let store = seeded_store(&dir, 4);
    for p in object_files(&dir) {
        let bytes = fs::read(&p).unwrap();
        fs::write(&p, &bytes[..bytes.len() - 1]).unwrap();
    }
    expect_kind(&audit_store(&store), AuditKind::StoreSizeMismatch);
    store.verify(true).unwrap();
    assert!(audit_store(&store).ok());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn store_missing_payload_is_unreadable() {
    let dir = tmp_dir("gone");
    let store = seeded_store(&dir, 5);
    for p in object_files(&dir) {
        fs::remove_file(&p).unwrap();
    }
    expect_kind(&audit_store(&store), AuditKind::StoreUnreadable);
    store.verify(true).unwrap();
    assert!(audit_store(&store).ok());
    let _ = fs::remove_dir_all(&dir);
}

/// End-to-end: a session that ran a prepared multiply, an expression
/// chain, and a delta update audits clean — plan table, expression
/// dataflow, pool accounting, and pins all verified statically.
#[test]
fn session_workloads_audit_clean() {
    let n = 4 * L;
    let s = SpammSession::new(&bundle(), SpammConfig::default()).unwrap();
    let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
    let b = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
    let ida = s.put(&a).unwrap();
    let idb = s.put(&b).unwrap();
    let plan = s.prepare(ida, idb, Approx::Tau(1e-4)).unwrap();
    s.wait(s.submit(plan).unwrap()).unwrap();

    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let c2 = g.spamm(leaf, leaf, Approx::Tau(1e-4));
    g.output(c2);
    let eplan = s.prepare_expr(&g, &[ida]).unwrap();
    s.wait(s.submit_expr(eplan).unwrap()).unwrap();

    let changed = [(0usize, 1usize)];
    let data = vec![0.01f32; L * L];
    s.update(ida, &changed, &data).unwrap();
    s.wait(s.submit(plan).unwrap()).unwrap();

    let r = s.audit().unwrap();
    assert!(r.ok(), "live session flagged: {:?}", r.violations);
    assert!(r.checks > 0, "a clean session audit must check something");
}
