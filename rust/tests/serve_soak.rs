//! Overload soak for the serving tier: concurrent tenants with a
//! Zipf-hot plan mix against tiny quotas.  Invariants under saturation:
//! sheds are *typed* replies on connections that stay open, per-tenant
//! budgets are isolated, every admitted ticket redeems (zero lost
//! tickets), each distinct product executes exactly once no matter how
//! many submits race it, and every byte that comes back is bitwise
//! identical to an in-process session.

mod common;

use std::sync::{Arc, Barrier};
use std::time::Duration;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, SpammSession};
use cuspamm::error::Error;
use cuspamm::matrix::Matrix;
use cuspamm::serve::{
    PutOutcome, RemoteApprox, RemoteOperandId, ServeClient, ServeServer, SubmitOutcome,
};

use common::bundle;

fn put_ok(c: &mut ServeClient, m: &Matrix) -> RemoteOperandId {
    match c.put(m).unwrap() {
        PutOutcome::Ok(id) => id,
        PutOutcome::QuotaExceeded(msg) => panic!("unexpected quota shed: {msg}"),
    }
}

#[test]
fn concurrent_zipf_hot_tenants_lose_no_tickets_and_stay_bitwise_identical() {
    const CLIENTS: usize = 5;
    const REQUESTS: usize = 10;
    let b = bundle();
    let n = 4 * b.lonum;
    let ma = Matrix::decay_algebraic(n, 0.1, 0.1, 71);
    let mb = Matrix::decay_algebraic(n, 0.1, 0.1, 72);
    // τ index 0 is the Zipf-hot plan every tenant hammers; 1..=CLIENTS
    // are per-tenant cold tails.
    let taus: Vec<f32> = std::iter::once(0.0)
        .chain((0..CLIENTS).map(|ci| 0.003 * (ci + 1) as f32))
        .collect();

    // In-process ground truth at every τ.
    let reference = SpammSession::new(&b, SpammConfig::default()).unwrap();
    let ra = reference.put(&ma).unwrap();
    let rb = reference.put(&mb).unwrap();
    let expected: Arc<Vec<Vec<f32>>> = Arc::new(
        taus.iter()
            .map(|&tau| {
                let plan = reference.prepare(ra, rb, Approx::Tau(tau)).unwrap();
                reference
                    .wait(reference.submit(plan).unwrap())
                    .unwrap()
                    .c
                    .data()
                    .to_vec()
            })
            .collect(),
    );

    let mut cfg = SpammConfig::default();
    cfg.queue_depth = 4;
    cfg.client_queue_depth = 2;
    let server = ServeServer::start(&b, cfg, "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|ci| {
            let (ma, mb) = (ma.clone(), mb.clone());
            let expected = expected.clone();
            let tau_own = taus[1 + ci];
            std::thread::spawn(move || -> (usize, usize) {
                let mut c = ServeClient::connect(addr, &format!("tenant-{ci}")).unwrap();
                let a = put_ok(&mut c, &ma);
                let bb = put_ok(&mut c, &mb);
                let hot = c.prepare(a, bb, RemoteApprox::Tau(0.0)).unwrap().id;
                let own = c.prepare(a, bb, RemoteApprox::Tau(tau_own)).unwrap().id;
                let (mut tickets, mut sheds) = (0, 0);
                for r in 0..REQUESTS {
                    let (plan, want) = if r % 3 != 0 {
                        (hot, &expected[0])
                    } else {
                        (own, &expected[1 + ci])
                    };
                    let t = loop {
                        match c.submit(plan).unwrap() {
                            SubmitOutcome::Ticket(t, _) => break t,
                            SubmitOutcome::Busy(_) | SubmitOutcome::QuotaExceeded(_) => {
                                sheds += 1;
                                std::thread::sleep(Duration::from_millis(1));
                            }
                        }
                    };
                    tickets += 1;
                    // Zero lost tickets: every admitted ticket redeems,
                    // and redeems the *right bits*.
                    let done = c.wait(t).unwrap();
                    assert_eq!(
                        done.c.data(),
                        &want[..],
                        "tenant-{ci} request {r} diverged from the in-process session"
                    );
                }
                (tickets, sheds)
            })
        })
        .collect();
    let mut tickets = 0u64;
    let mut sheds = 0u64;
    for h in handles {
        let (t, s) = h.join().expect("soak client panicked");
        tickets += t as u64;
        sheds += s as u64;
    }
    assert_eq!(tickets, (CLIENTS * REQUESTS) as u64, "every request must eventually redeem");

    let mut probe = ServeClient::connect(addr, "probe").unwrap();
    let stats = probe.stats().unwrap();
    // Each distinct result key executes exactly once, ever — the hot
    // plan plus one tail per tenant — regardless of how the submits
    // interleaved.
    assert_eq!(
        stats.executed,
        (1 + CLIENTS) as u64,
        "distinct products must execute exactly once (sheds retried: {sheds})"
    );
    // Ticket conservation: every admitted submit was exactly one of
    // leader / batched follower / result-cache hit.
    assert_eq!(
        stats.executed + stats.batched + stats.result_cache_hits,
        tickets,
        "admission outcomes must partition the admitted tickets"
    );
    assert_eq!(stats.shed_quota + stats.shed_busy, sheds);
    drop(probe);
    server.shutdown();
}

#[test]
fn racing_same_plan_submits_execute_exactly_once() {
    const RACERS: usize = 8;
    let b = bundle();
    let n = 4 * b.lonum;
    let m = Matrix::decay_algebraic(n, 0.1, 0.1, 73);

    let reference = SpammSession::new(&b, SpammConfig::default()).unwrap();
    let rid = reference.put(&m).unwrap();
    let rplan = reference.prepare(rid, rid, Approx::Tau(0.0)).unwrap();
    let want = reference
        .wait(reference.submit(rplan).unwrap())
        .unwrap()
        .c
        .data()
        .to_vec();

    let server = ServeServer::start(&b, SpammConfig::default(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let barrier = Arc::new(Barrier::new(RACERS));
    let handles: Vec<_> = (0..RACERS)
        .map(|_| {
            let m = m.clone();
            let want = want.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || -> bool {
                let mut c = ServeClient::connect(addr, "race").unwrap();
                let id = put_ok(&mut c, &m);
                let plan = c.prepare(id, id, RemoteApprox::Tau(0.0)).unwrap().id;
                barrier.wait();
                let t = match c.submit(plan).unwrap() {
                    SubmitOutcome::Ticket(t, _) => t,
                    other => panic!("racing submit shed with default quotas: {other:?}"),
                };
                let done = c.wait(t).unwrap();
                assert_eq!(done.c.data(), &want[..], "racer diverged");
                done.executed
            })
        })
        .collect();
    let executed_flags: Vec<bool> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(
        executed_flags.iter().filter(|&&e| e).count(),
        1,
        "exactly one racer is the leader; followers and cache hits report executed=false"
    );
    let mut probe = ServeClient::connect(addr, "probe").unwrap();
    let stats = probe.stats().unwrap();
    assert_eq!(stats.executed, 1, "the device ran the shared plan exactly once");
    assert_eq!(
        stats.batched + stats.result_cache_hits,
        (RACERS - 1) as u64,
        "everyone else coalesced onto the leader or the cache"
    );
    drop(probe);
    server.shutdown();
}

#[test]
fn tenant_quotas_are_isolated_and_typed() {
    let b = bundle();
    let n = 4 * b.lonum;
    let mut cfg = SpammConfig::default();
    // Budget for exactly one n×n f32 operand per tenant.
    cfg.client_store_budget = n * n * 4;
    let server = ServeServer::start(&b, cfg, "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let m1 = Matrix::decay_algebraic(n, 0.1, 0.1, 74);
    let m2 = Matrix::decay_algebraic(n, 0.1, 0.1, 75);

    let mut alice = ServeClient::connect(addr, "alice").unwrap();
    let a1 = put_ok(&mut alice, &m1);
    match alice.put(&m2).unwrap() {
        PutOutcome::QuotaExceeded(msg) => {
            assert!(msg.contains("store budget"), "untyped shed message: {msg}")
        }
        PutOutcome::Ok(_) => panic!("second put must exceed a one-operand budget"),
    }
    // The shed cost alice nothing but the request: her connection and
    // her first operand both still work.
    let plan = alice.prepare(a1, a1, RemoteApprox::Tau(0.0)).unwrap();
    assert_eq!((plan.rows, plan.cols), (n, n));

    // Bob's budget is bob's: alice exhausting hers must not shed him.
    let mut bob = ServeClient::connect(addr, "bob").unwrap();
    let b1 = put_ok(&mut bob, &m2);

    // Ownership is per-tenant even though the underlying store dedups
    // content: alice cannot prepare over bob's handle.
    let stolen = alice.prepare(b1, b1, RemoteApprox::Tau(0.0)).unwrap_err();
    assert!(matches!(stolen, Error::Session(_)), "{stolen}");

    // Release refunds the budget: alice can swap operands.
    alice.release_plan(plan.id).unwrap();
    alice.release(a1).unwrap();
    let a2 = put_ok(&mut alice, &m2);
    let plan2 = alice.prepare(a2, a2, RemoteApprox::Tau(0.0)).unwrap();
    match alice.submit(plan2.id).unwrap() {
        SubmitOutcome::Ticket(t, _) => {
            let done = alice.wait(t).unwrap();
            assert_eq!((done.c.rows(), done.c.cols()), (n, n));
        }
        other => panic!("post-refund submit shed: {other:?}"),
    }
    let stats = alice.stats().unwrap();
    assert!(stats.shed_quota >= 1);
    drop((alice, bob));
    server.shutdown();
}

#[test]
fn inflight_depth_sheds_deterministically() {
    let b = bundle();
    let n = 4 * b.lonum;
    let mut cfg = SpammConfig::default();
    cfg.client_queue_depth = 1;
    let server = ServeServer::start(&b, cfg, "127.0.0.1:0").unwrap();
    let mut c = ServeClient::connect(server.local_addr(), "narrow").unwrap();
    let m = Matrix::decay_algebraic(n, 0.1, 0.1, 76);
    let id = put_ok(&mut c, &m);
    let p1 = c.prepare(id, id, RemoteApprox::Tau(0.0)).unwrap().id;
    let p2 = c.prepare(id, id, RemoteApprox::Tau(0.05)).unwrap().id;
    // Inflight is charged at admission and released at wait, so the
    // second back-to-back cold submit sheds regardless of device timing.
    let t1 = match c.submit(p1).unwrap() {
        SubmitOutcome::Ticket(t, _) => t,
        other => panic!("first submit must be admitted: {other:?}"),
    };
    match c.submit(p2).unwrap() {
        SubmitOutcome::QuotaExceeded(msg) => {
            assert!(msg.contains("inflight"), "untyped shed message: {msg}")
        }
        other => panic!("depth-1 second submit must shed typed: {other:?}"),
    }
    c.wait(t1).unwrap();
    // The wait released the slot.
    match c.submit(p2).unwrap() {
        SubmitOutcome::Ticket(t, _) => {
            c.wait(t).unwrap();
        }
        other => panic!("post-wait submit shed: {other:?}"),
    }
    // Cache hits bypass the depth budget entirely: with a cold submit
    // holding the single inflight slot, warm re-submits still admit.
    let p3 = c.prepare(id, id, RemoteApprox::Tau(0.1)).unwrap().id;
    let t_hold = match c.submit(p3).unwrap() {
        SubmitOutcome::Ticket(t, cached) => {
            assert!(!cached);
            t
        }
        other => panic!("cold p3 submit shed with an empty slot: {other:?}"),
    };
    for warm in [p1, p2] {
        match c.submit(warm).unwrap() {
            SubmitOutcome::Ticket(t, cached) => {
                assert!(cached, "executed plans re-submit as cache hits");
                let done = c.wait(t).unwrap();
                assert!(!done.executed);
            }
            other => panic!("cache hits must not be charged against the depth: {other:?}"),
        }
    }
    c.wait(t_hold).unwrap();
    drop(c);
    server.shutdown();
}

#[test]
fn global_saturation_sheds_busy_and_admitted_tickets_all_redeem() {
    const FLOOD: usize = 16;
    let b = bundle();
    let n = 8 * b.lonum;
    let mut cfg = SpammConfig::default();
    cfg.queue_depth = 1;
    let server = ServeServer::start(&b, cfg, "127.0.0.1:0").unwrap();
    let mut c = ServeClient::connect(server.local_addr(), "flood").unwrap();
    let m = Matrix::decay_algebraic(n, 0.1, 0.1, 77);
    let id = put_ok(&mut c, &m);
    // Distinct-τ plans: none can coalesce or ride the cache, so every
    // admission takes a real queue slot.
    let plans: Vec<_> = (0..FLOOD)
        .map(|i| {
            c.prepare(id, id, RemoteApprox::Tau(0.011 * (i + 1) as f32))
                .unwrap()
                .id
        })
        .collect();
    let mut admitted = Vec::new();
    let mut saw_busy = false;
    for &p in &plans {
        match c.submit(p).unwrap() {
            SubmitOutcome::Ticket(t, cached) => {
                assert!(!cached);
                admitted.push(t);
            }
            SubmitOutcome::Busy(msg) => {
                assert!(msg.contains("admission queue"), "untyped busy message: {msg}");
                saw_busy = true;
                break;
            }
            SubmitOutcome::QuotaExceeded(msg) => {
                panic!("global saturation must shed Busy, not quota: {msg}")
            }
        }
    }
    assert!(saw_busy, "flooding {FLOOD} cold submits at queue_depth=1 must saturate the session");
    assert!(!admitted.is_empty(), "at least the first submit is admitted");
    // Zero lost tickets: the shed dropped only the shed request.
    for (i, &t) in admitted.iter().enumerate() {
        let done = c.wait(t).unwrap();
        assert!(done.executed, "admitted flood ticket {i} must execute");
        assert_eq!((done.c.rows(), done.c.cols()), (n, n));
    }
    // The shed plan itself is still servable afterwards.
    match c.submit(plans[FLOOD - 1]).unwrap() {
        SubmitOutcome::Ticket(t, _) => {
            c.wait(t).unwrap();
        }
        SubmitOutcome::Busy(_) => {} // the queue may still be draining
        SubmitOutcome::QuotaExceeded(msg) => panic!("{msg}"),
    }
    let stats = c.stats().unwrap();
    assert!(stats.shed_busy >= 1);
    drop(c);
    server.shutdown();
}
