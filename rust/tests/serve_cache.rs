//! Result-cache correctness for the serving tier: warm re-submits are
//! answered from the fingerprint-keyed cache with zero device dispatches,
//! repair-aware invalidation after an incremental update drops *only* the
//! cached products the repair actually touched (untouched entries migrate
//! to their post-update keys bit-for-bit), and disabling the cache is
//! bitwise inert.

mod common;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, SpammSession};
use cuspamm::matrix::Matrix;
use cuspamm::serve::{
    PutOutcome, RemoteApprox, RemoteCompletion, RemoteOperandId, RemotePlanId, ServeClient,
    ServeServer, SubmitOutcome,
};

use common::bundle;

fn put_ok(c: &mut ServeClient, m: &Matrix) -> RemoteOperandId {
    match c.put(m).unwrap() {
        PutOutcome::Ok(id) => id,
        PutOutcome::QuotaExceeded(msg) => panic!("unexpected quota shed: {msg}"),
    }
}

fn submit_wait(c: &mut ServeClient, plan: RemotePlanId) -> (bool, RemoteCompletion) {
    match c.submit(plan).unwrap() {
        SubmitOutcome::Ticket(t, cached) => (cached, c.wait(t).unwrap()),
        other => panic!("submit shed on an unloaded server: {other:?}"),
    }
}

#[test]
fn warm_resubmits_hit_the_cache_with_zero_dispatches() {
    let b = bundle();
    let n = 4 * b.lonum;
    let server = ServeServer::start(&b, SpammConfig::default(), "127.0.0.1:0").unwrap();
    let mut c = ServeClient::connect(server.local_addr(), "warm").unwrap();
    let m = Matrix::decay_algebraic(n, 0.1, 0.1, 81);
    let id = put_ok(&mut c, &m);
    let plan = c.prepare(id, id, RemoteApprox::Tau(0.0)).unwrap().id;

    let (cold_cached, cold) = submit_wait(&mut c, plan);
    assert!(!cold_cached);
    assert!(cold.executed, "the first submit executes on the device");
    for round in 1..4 {
        let (cached, warm) = submit_wait(&mut c, plan);
        assert!(cached, "round {round}: warm submit must be admitted from the cache");
        assert!(!warm.executed, "round {round}: a cache hit dispatches nothing");
        assert_eq!(warm.compiles, 0, "round {round}: a cache hit compiles nothing");
        assert_eq!(warm.compute_secs, 0.0, "round {round}: a cache hit charges no compute");
        assert_eq!(warm.c.data(), cold.c.data(), "round {round}: cached bits diverged");
        assert_eq!(warm.tau.to_bits(), cold.tau.to_bits());
        assert_eq!(warm.valid_ratio, cold.valid_ratio);
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.result_cache_hits, 3);
    assert_eq!(stats.result_cache_misses, 1);
    assert_eq!(stats.result_cache_len, 1);
    assert_eq!(stats.executed, 1);
    drop(c);
    server.shutdown();
}

#[test]
fn disabling_the_result_cache_is_bitwise_inert() {
    let b = bundle();
    let n = 4 * b.lonum;
    let m = Matrix::decay_algebraic(n, 0.1, 0.1, 82);

    let on = ServeServer::start(&b, SpammConfig::default(), "127.0.0.1:0").unwrap();
    let mut c_on = ServeClient::connect(on.local_addr(), "on").unwrap();
    let id = put_ok(&mut c_on, &m);
    let plan = c_on.prepare(id, id, RemoteApprox::Tau(1e-4)).unwrap().id;
    let (_, first_on) = submit_wait(&mut c_on, plan);
    let (warm_cached, warm_on) = submit_wait(&mut c_on, plan);
    assert!(warm_cached);

    let mut cfg = SpammConfig::default();
    cfg.result_cache_enabled = false;
    let off = ServeServer::start(&b, cfg, "127.0.0.1:0").unwrap();
    let mut c_off = ServeClient::connect(off.local_addr(), "off").unwrap();
    let id = put_ok(&mut c_off, &m);
    let plan = c_off.prepare(id, id, RemoteApprox::Tau(1e-4)).unwrap().id;
    let (c1, first_off) = submit_wait(&mut c_off, plan);
    let (c2, second_off) = submit_wait(&mut c_off, plan);
    assert!(!c1 && !c2, "a disabled cache never admits from the cache");
    assert!(first_off.executed && second_off.executed, "with the cache off every submit executes");
    // The kill switch changes scheduling of work, never bits.
    assert_eq!(first_off.c.data(), first_on.c.data());
    assert_eq!(second_off.c.data(), warm_on.c.data());
    let stats = c_off.stats().unwrap();
    assert_eq!(stats.result_cache_hits, 0);
    assert_eq!(stats.result_cache_len, 0);
    assert_eq!(stats.executed, 2);
    drop((c_on, c_off));
    on.shutdown();
    off.shutdown();
}

/// Zero an operand's last tile row and column so every product touching
/// tile (T-1, T-1) is norm-pruned at any τ > 0.
fn with_cold_border(n: usize, l: usize, seed: u64) -> Matrix {
    let mut m = Matrix::decay_algebraic(n, 0.1, 0.1, seed);
    let t = n / l;
    for r in 0..n {
        for c in 0..n {
            if r >= (t - 1) * l || c >= (t - 1) * l {
                m[(r, c)] = 0.0;
            }
        }
    }
    m
}

#[test]
fn update_invalidates_only_repair_touched_products() {
    let b = bundle();
    let l = b.lonum;
    let n = 4 * l;
    let tau = 0.01f32;
    let server = ServeServer::start(&b, SpammConfig::default(), "127.0.0.1:0").unwrap();
    let mut c = ServeClient::connect(server.local_addr(), "updater").unwrap();

    // Three independent products: u's update dirties its cached result,
    // v is never updated, w's update lands only in its norm-pruned cold
    // border so the surviving products are untouched.
    let mu = Matrix::decay_algebraic(n, 0.1, 0.1, 83);
    let mv = Matrix::decay_algebraic(n, 0.1, 0.1, 84);
    let mw = with_cold_border(n, l, 85);
    let u = put_ok(&mut c, &mu);
    let v = put_ok(&mut c, &mv);
    let w = put_ok(&mut c, &mw);
    let plan_u = c.prepare(u, u, RemoteApprox::Tau(0.0)).unwrap().id;
    let plan_v = c.prepare(v, v, RemoteApprox::Tau(0.0)).unwrap().id;
    let plan_w = c.prepare(w, w, RemoteApprox::Tau(tau)).unwrap().id;
    let (_, cold_u) = submit_wait(&mut c, plan_u);
    let (_, cold_v) = submit_wait(&mut c, plan_v);
    let (_, cold_w) = submit_wait(&mut c, plan_w);

    // u: rewrite tile (0,0) — it feeds surviving products, so the cached
    // product is stale and must drop.
    let hot_tile = vec![0.5f32; l * l];
    let rep_u = c.update(u, &[(0, 0)], &hot_tile).unwrap();
    assert_eq!(rep_u.tiles_changed, 1);
    assert_eq!(rep_u.invalidated, 1, "u's cached product is repair-touched");
    assert_eq!(rep_u.rekeyed, 0);

    // w: rewrite tile (T-1, T-1) with values tiny enough that its norm
    // products stay below τ — the schedule's surviving products never
    // read it, so the cached bits remain exact and migrate keys.
    let cold_tile = vec![1e-4f32; l * l];
    let rep_w = c.update(w, &[(n / l - 1, n / l - 1)], &cold_tile).unwrap();
    assert_eq!(rep_w.tiles_changed, 1);
    assert_eq!(rep_w.invalidated, 0, "w's surviving products are untouched");
    assert_eq!(rep_w.rekeyed, 1, "w's cached product migrates to the new key");

    // v was never part of either update: still a pure hit.
    let (cached_v, warm_v) = submit_wait(&mut c, plan_v);
    assert!(cached_v && !warm_v.executed);
    assert_eq!(warm_v.c.data(), cold_v.c.data());

    // w re-submits as a hit under its migrated key, and the cached bits
    // equal a from-scratch session over the *updated* operand.
    let (cached_w, warm_w) = submit_wait(&mut c, plan_w);
    assert!(cached_w, "rekeyed entries must still hit");
    assert!(!warm_w.executed);
    assert_eq!(warm_w.c.data(), cold_w.c.data());
    let mut mw_updated = mw.clone();
    for r in 0..l {
        for cc in 0..l {
            mw_updated[((n - l) + r, (n - l) + cc)] = 1e-4;
        }
    }
    let s = SpammSession::new(&b, SpammConfig::default()).unwrap();
    let sid = s.put(&mw_updated).unwrap();
    let splan = s.prepare(sid, sid, Approx::Tau(tau)).unwrap();
    let direct_w = s.wait(s.submit(splan).unwrap()).unwrap();
    assert_eq!(
        warm_w.c.data(),
        direct_w.c.data(),
        "the migrated cache entry must equal recomputing over the updated operand"
    );

    // u re-submits cold: the invalidation forced a re-execution whose
    // bits reflect the new tile — and match a from-scratch session.
    let (cached_u, fresh_u) = submit_wait(&mut c, plan_u);
    assert!(!cached_u, "invalidated entries must miss");
    assert!(fresh_u.executed);
    assert_ne!(fresh_u.c.data(), cold_u.c.data(), "rewriting a hot tile must change the product");
    let mut mu_updated = mu.clone();
    for r in 0..l {
        for cc in 0..l {
            mu_updated[(r, cc)] = 0.5;
        }
    }
    let s2 = SpammSession::new(&b, SpammConfig::default()).unwrap();
    let sid2 = s2.put(&mu_updated).unwrap();
    let splan2 = s2.prepare(sid2, sid2, Approx::Tau(0.0)).unwrap();
    let direct_u = s2.wait(s2.submit(splan2).unwrap()).unwrap();
    assert_eq!(fresh_u.c.data(), direct_u.c.data());

    let stats = c.stats().unwrap();
    assert_eq!(stats.result_cache_invalidations, 1);
    assert_eq!(stats.result_cache_rekeys, 1);
    drop(c);
    server.shutdown();
}
