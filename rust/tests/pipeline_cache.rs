//! Integration tests for the stage-pipelined executor and the
//! normmap/schedule caches (the PR-1 execution-layer redesign).

mod common;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::Matrix;
use cuspamm::spamm::power::spamm_power;
use cuspamm::spamm::reference::spamm_flat_host;
use cuspamm::spamm::SpammEngine;

use common::bundle;

#[test]
fn repeated_multiply_hits_caches_and_is_bit_identical() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 51);
    let x = Matrix::decay_exponential(128, 1.0, 0.5, 52);
    let tau = 1e-4f32;

    let (c_cold, s_cold) = engine.multiply_with_stats(&a, &x, tau).unwrap();
    assert_eq!(s_cold.norm_cache_hits, 0);
    assert_eq!(s_cold.norm_cache_misses, 2);
    assert_eq!(s_cold.schedule_cache_misses, 1);

    let (c_warm, s_warm) = engine.multiply_with_stats(&a, &x, tau).unwrap();
    assert_eq!(s_warm.norm_cache_hits, 2, "both operand normmaps must hit");
    assert_eq!(s_warm.norm_cache_misses, 0);
    assert_eq!(s_warm.schedule_cache_hits, 1);
    assert_eq!(s_warm.schedule_cache_misses, 0);

    // Cache hits must not change a single bit of the result.
    assert_eq!(c_cold.data(), c_warm.data());

    // Engine-level counters agree.
    assert!(engine.caches().norms.hits() >= 2);
    assert!(engine.caches().schedules.hits() >= 1);
}

#[test]
fn tau_change_rebuilds_schedule_but_reuses_norms() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 53);
    let x = Matrix::decay_exponential(128, 1.0, 0.5, 54);
    engine.multiply(&a, &x, 1e-4).unwrap();
    let (_, s) = engine.multiply_with_stats(&a, &x, 1e-3).unwrap();
    assert_eq!(s.norm_cache_hits, 2);
    assert_eq!(s.schedule_cache_hits, 0, "different τ is a different key");
    assert_eq!(s.schedule_cache_misses, 1);
}

#[test]
fn no_cache_flag_bypasses_caches() {
    let b = bundle();
    let mut cfg = SpammConfig::default();
    cfg.cache_enabled = false;
    let engine = SpammEngine::new(&b, cfg).unwrap();
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 55);
    for _ in 0..2 {
        let (_, s) = engine.multiply_with_stats(&a, &a, 1e-4).unwrap();
        assert_eq!(s.norm_cache_hits + s.norm_cache_misses, 0);
        assert_eq!(s.schedule_cache_hits + s.schedule_cache_misses, 0);
    }
    assert_eq!(engine.caches().norms.hits() + engine.caches().norms.misses(), 0);
}

#[test]
fn cached_and_uncached_paths_agree_bitwise() {
    let b = bundle();
    let cached = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let mut cfg = SpammConfig::default();
    cfg.cache_enabled = false;
    let uncached = SpammEngine::new(&b, cfg).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 56);
    let x = Matrix::decay_exponential(128, 1.0, 0.5, 57);
    for tau in [0.0f32, 1e-4] {
        let c1 = cached.multiply(&a, &x, tau).unwrap();
        let c2 = cached.multiply(&a, &x, tau).unwrap(); // cache hit
        let c3 = uncached.multiply(&a, &x, tau).unwrap();
        assert_eq!(c1.data(), c2.data());
        assert_eq!(c1.data(), c3.data());
    }
}

#[test]
fn fingerprint_keyed_multiply_matches_hashed_path() {
    use cuspamm::matrix::tiling::PaddedMatrix;
    use cuspamm::spamm::cache::fingerprint;

    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 70);
    let x = Matrix::decay_exponential(128, 1.0, 0.5, 71);
    let (c_hashed, _) = engine.multiply_with_stats(&a, &x, 1e-4).unwrap();
    // The by-id entry point: operands pre-padded, fingerprints known —
    // identical bits, and the norm cache hits without re-hashing.
    let pa = PaddedMatrix::new(&a, 32);
    let px = PaddedMatrix::new(&x, 32);
    let (fa, fx) = (fingerprint(&pa), fingerprint(&px));
    let (c_keyed, stats) = engine
        .multiply_prepared_with_stats(&pa, fa, &px, fx, 1e-4)
        .unwrap();
    assert_eq!(c_hashed.data(), c_keyed.data());
    assert_eq!(stats.norm_cache_hits, 2, "keyed lookups must hit the shared cache");
    assert_eq!(stats.schedule_cache_hits, 1);
}

#[test]
fn zero_surviving_products_returns_exact_zeros() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::randn(96, 96, 58);
    let (c, stats) = engine.multiply_with_stats(&a, &a, f32::MAX).unwrap();
    assert_eq!(stats.valid_products, 0);
    assert_eq!(stats.batches, 0, "no kernel launches for an empty schedule");
    assert_eq!(c.fnorm(), 0.0);
    assert!(c.data().iter().all(|&x| x == 0.0));
}

#[test]
fn pipelined_execution_matches_host_reference() {
    let b = bundle();
    let mut cfg = SpammConfig::default();
    cfg.pipeline_depth = 3;
    let engine = SpammEngine::new(&b, cfg).unwrap();
    let a = Matrix::decay_exponential(256, 1.0, 0.5, 59);
    let x = Matrix::decay_exponential(256, 1.0, 0.5, 60);
    let tau = engine.tune_tau(&a, &x, 0.3).unwrap().tau;
    let (c, stats) = engine.multiply_with_stats(&a, &x, tau).unwrap();
    let want = spamm_flat_host(&a, &x, tau, b.lonum).unwrap();
    let rel = c.error_fnorm(&want).unwrap() / want.fnorm().max(1e-30);
    assert!(rel < 1e-5, "rel err {rel}");
    assert_eq!(stats.pipeline_depth, 3);
    assert!(stats.batches >= 1);
    assert!(stats.exec_span_secs > 0.0);
    assert!(stats.exec_span_secs <= stats.total_secs + 1e-9);
}

#[test]
fn pipeline_depth_does_not_change_results() {
    let b = bundle();
    let mut results = Vec::new();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 61);
    let x = Matrix::decay_exponential(128, 1.0, 0.5, 62);
    for depth in [1usize, 2, 4] {
        let mut cfg = SpammConfig::default();
        cfg.pipeline_depth = depth;
        let engine = SpammEngine::new(&b, cfg).unwrap();
        results.push(engine.multiply(&a, &x, 1e-4).unwrap());
    }
    assert_eq!(results[0].data(), results[1].data());
    assert_eq!(results[0].data(), results[2].data());
}

#[test]
fn engine_rejects_mismatched_inner_dims_that_pad_alike() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    // 17 and 20 both pad to a single 32-tile, so the tile grids agree and
    // the old code silently produced garbage.
    let a = Matrix::randn(16, 17, 63);
    let x = Matrix::randn(20, 8, 64);
    assert!(engine.multiply(&a, &x, 0.0).is_err());
    assert!(engine.multiply_with_stats(&a, &x, 0.0).is_err());
    assert!(engine.tune_tau(&a, &x, 0.1).is_err());
}

#[test]
fn coordinator_rejects_mismatched_inner_dims() {
    let b = bundle();
    let mut cfg = SpammConfig::default();
    cfg.devices = 2;
    let coord = Coordinator::new(&b, cfg).unwrap();
    let a = Matrix::randn(16, 17, 65);
    let x = Matrix::randn(20, 8, 66);
    assert!(coord.multiply(&a, &x, 0.0).is_err());
    assert!(coord.tune_tau(&a, &x, 0.1).is_err());
}

#[test]
fn power_chain_reuses_cached_operand_norms() {
    let b = bundle();
    let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 67);
    let r = spamm_power(&coord, &a, 4, 1e-5).unwrap();
    assert_eq!(r.steps.len(), 3);
    // The constant right-hand operand A must hit the norm cache on every
    // iteration after the first.
    assert!(
        coord.caches().norms.hits() >= 2,
        "expected ≥2 norm-cache hits, saw {}",
        coord.caches().norms.hits()
    );
}

#[test]
fn coordinator_cached_multiply_is_bit_identical() {
    let b = bundle();
    let mut cfg = SpammConfig::default();
    cfg.devices = 2;
    let coord = Coordinator::new(&b, cfg).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.55, 68);
    let x = Matrix::decay_exponential(128, 1.0, 0.55, 69);
    let r1 = coord.multiply(&a, &x, 1e-4).unwrap();
    let r2 = coord.multiply(&a, &x, 1e-4).unwrap();
    assert_eq!(r1.c.data(), r2.c.data());
    assert!(coord.caches().schedules.hits() >= 1);
    // Per-device pipeline-stage clocks are aggregated into the report.
    assert!(r1.stage.batches >= 1);
    assert!(r1.stage.exec_span_secs > 0.0);
    assert!(r1.stage.exec_secs > 0.0);
}
