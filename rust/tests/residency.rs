//! Integration tests for the device-resident tile pool: bitwise identity
//! of the resident and `--no-residency` paths across iterative workloads,
//! warm-pool transfer savings, and eviction behavior under tiny budgets.

mod common;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::Matrix;
use cuspamm::proptest::{forall_ok, gen, PropConfig};
use cuspamm::spamm::power::spamm_power;
use cuspamm::spamm::purification::{initial_density, mcweeny_purify};
use cuspamm::spamm::SpammEngine;

use common::bundle;

fn cfg_residency(on: bool) -> SpammConfig {
    let mut cfg = SpammConfig::default();
    cfg.residency_enabled = on;
    cfg
}

#[test]
fn resident_and_no_residency_agree_bitwise_on_power_and_purification() {
    // The ISSUE's property: across power iteration + purification, the
    // resident path must produce bit-identical results to --no-residency.
    let b = bundle();
    forall_ok(
        PropConfig { cases: 4, seed: 0xBEEF },
        |rng| {
            (
                gen::pow2_in(rng, 64, 128),
                gen::usize_in(rng, 1, 1_000_000) as u64,
                gen::f32_in(rng, 1e-5, 1e-3),
            )
        },
        |&(n, seed, tau)| {
            let with = Coordinator::new(&b, cfg_residency(true)).map_err(|e| e.to_string())?;
            let without = Coordinator::new(&b, cfg_residency(false)).map_err(|e| e.to_string())?;

            let a = Matrix::decay_exponential(n, 1.0, 0.5, seed);
            let p1 = spamm_power(&with, &a, 3, tau).map_err(|e| e.to_string())?;
            let p2 = spamm_power(&without, &a, 3, tau).map_err(|e| e.to_string())?;
            if p1.value.data() != p2.value.data() {
                return Err(format!("power(n={n}, τ={tau}) differs between paths"));
            }

            let p0 = initial_density(n, seed);
            let r1 = mcweeny_purify(&with, &p0, tau, 2, 0.0).map_err(|e| e.to_string())?;
            let r2 = mcweeny_purify(&without, &p0, tau, 2, 0.0).map_err(|e| e.to_string())?;
            if r1.p.data() != r2.p.data() {
                return Err(format!("purification(n={n}, τ={tau}) differs between paths"));
            }
            Ok(())
        },
    );
}

#[test]
fn warm_pool_skips_transfers_on_repeated_multiply() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 101);
    let x = Matrix::decay_exponential(128, 1.0, 0.5, 102);
    let tau = 1e-4f32;

    let (c_cold, cold) = engine.multiply_with_stats(&a, &x, tau).unwrap();
    assert!(cold.transfer_bytes > 0, "cold call must upload tiles");
    // (A cold call can still *hit* tiles a previous chunk of the same call
    // uploaded — only misses are guaranteed here.)
    assert!(cold.residency_misses > 0);

    let (c_warm, warm) = engine.multiply_with_stats(&a, &x, tau).unwrap();
    // The acceptance criterion: a warm pool transfers ≥ 4x fewer bytes.
    assert!(
        warm.transfer_bytes * 4 <= cold.transfer_bytes,
        "warm transfers {} vs cold {}",
        warm.transfer_bytes,
        cold.transfer_bytes
    );
    assert!(warm.residency_hits > 0);
    assert_eq!(warm.residency_misses, 0, "every operand tile is resident");
    assert!(warm.transfer_saved_bytes >= cold.transfer_bytes);
    assert_eq!(c_cold.data(), c_warm.data());

    // Pool-level counters agree with the per-call stats.
    let pool = engine.residency().expect("residency on by default");
    let s = pool.stats();
    assert_eq!(s.misses as usize, cold.residency_misses);
    assert!(s.hits as usize >= warm.residency_hits);
    assert_eq!(s.uploaded_bytes, cold.transfer_bytes);
}

#[test]
fn no_residency_flag_disables_pool() {
    let b = bundle();
    let engine = SpammEngine::new(&b, cfg_residency(false)).unwrap();
    assert!(engine.residency().is_none());
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 103);
    for _ in 0..2 {
        let (_, s) = engine.multiply_with_stats(&a, &a, 1e-4).unwrap();
        assert_eq!(s.residency_hits, 0);
        assert_eq!(s.residency_misses, 0);
        // Every call re-uploads: nothing is resident across calls.
        assert!(s.transfer_bytes > 0);
    }
}

#[test]
fn eviction_under_tiny_budget_stays_correct() {
    let b = bundle();
    let tile_bytes = 32 * 32 * 4;
    let mut cfg = SpammConfig::default();
    cfg.device_mem_budget = 3 * tile_bytes; // far fewer than the operands' tiles
    cfg.max_tile_batch = 16; // many small chunks → constant pool churn
    let tiny = SpammEngine::new(&b, cfg).unwrap();
    let roomy = SpammEngine::new(&b, SpammConfig::default()).unwrap();

    // τ = 0 keeps all 8·8·8 products → 32 sixteen-product chunks; channel
    // backpressure guarantees later chunks stage after earlier chunks'
    // pins dropped, so the 3-tile budget must evict continuously.
    let a = Matrix::decay_exponential(256, 1.0, 0.5, 104);
    let x = Matrix::decay_exponential(256, 1.0, 0.5, 105);
    let (c_tiny, _) = tiny.multiply_with_stats(&a, &x, 0.0).unwrap();
    let (c_roomy, _) = roomy.multiply_with_stats(&a, &x, 0.0).unwrap();
    assert_eq!(c_tiny.data(), c_roomy.data(), "eviction must not change results");
    // A second call still works (tiles churn through the tiny pool).
    let (c2, _) = tiny.multiply_with_stats(&a, &x, 0.0).unwrap();
    assert_eq!(c2.data(), c_roomy.data());
    let s = tiny.residency().unwrap().stats();
    assert!(
        s.evictions > 0,
        "a 3-tile budget over an 8x8 tile grid must evict, stats {s:?}"
    );
}

#[test]
fn coordinator_reports_per_device_transfer_clocks_and_warm_reuse() {
    let b = bundle();
    let mut cfg = SpammConfig::default();
    cfg.devices = 2;
    let coord = Coordinator::new(&b, cfg).unwrap();
    assert_eq!(coord.residency_pools().len(), 2);

    let a = Matrix::decay_exponential(128, 1.0, 0.55, 106);
    let x = Matrix::decay_exponential(128, 1.0, 0.55, 107);
    let r1 = coord.multiply(&a, &x, 1e-4).unwrap();
    assert_eq!(r1.device_transfer_secs.len(), 2);
    assert!(r1.stage.transfer_bytes > 0);

    // Second multiply on the same operands: per-device pools are warm, so
    // phase-3 transfers vanish entirely.
    let r2 = coord.multiply(&a, &x, 1e-4).unwrap();
    assert_eq!(r1.c.data(), r2.c.data());
    assert!(
        r2.stage.transfer_bytes * 4 <= r1.stage.transfer_bytes,
        "warm device pools must cut transfers ≥4x: {} vs {}",
        r2.stage.transfer_bytes,
        r1.stage.transfer_bytes
    );
    assert!(r2.stage.residency_hits > 0);
    assert!(r1.summary_line().contains("transfers"));
}

#[test]
fn power_chain_reuses_constant_operand_tiles() {
    // A^k keeps multiplying by the constant A: its tiles must stay
    // resident across iterations (the §3.3 A-block reuse across repeats).
    let b = bundle();
    let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 108);
    spamm_power(&coord, &a, 4, 1e-5).unwrap();
    let pool = &coord.residency_pools()[0];
    let s = pool.stats();
    assert!(
        s.hits > 0,
        "constant operand tiles must hit the pool across the chain"
    );
    assert!(s.saved_bytes > 0);
}

#[test]
fn within_chunk_duplicate_tiles_are_staged_once() {
    // τ = 0 on a decay matrix keeps every product: each A-tile of a row
    // appears in every output tile of that row, so the gather stage must
    // dedupe heavily even on the very first (all-miss) call.
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 109);
    let (_, s) = engine.multiply_with_stats(&a, &a, 0.0).unwrap();
    // 4x4 tile grid, 64 products, ≤ 2·16 unique operand tiles (A ≡ B here
    // contributes per-operand entries): far fewer uploads than slots.
    let tile_bytes = (32 * 32 * 4) as u64;
    let slots_bytes = 2 * 64 * tile_bytes; // 64 products × two operands
    assert!(
        s.transfer_bytes + s.transfer_saved_bytes >= slots_bytes,
        "accounting covers every slot reference"
    );
    assert!(
        s.transfer_bytes <= 2 * 16 * tile_bytes,
        "uploads bounded by unique tiles, got {}",
        s.transfer_bytes
    );
    assert!(s.transfer_saved_bytes > 0);
}
