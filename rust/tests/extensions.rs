//! Integration tests for the extension features (paper §2.1 general form,
//! §3.4 future-work SUMMA, error analysis, matrix powers) plus failure
//! injection on the artifact/runtime layers.

mod common;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Coordinator, SummaCoordinator};
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::runtime::{ArtifactBundle, Runtime};
use cuspamm::spamm::error_analysis::apriori_error_bound;
use cuspamm::spamm::normmap::normmap;
use cuspamm::spamm::power::spamm_power;
use cuspamm::spamm::SpammEngine;

use common::bundle;

#[test]
fn axpby_general_form() {
    // C ← α·AB + β·C with α=2, β=−1 against a host reference.
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_algebraic(96, 0.1, 0.1, 31);
    let x = Matrix::decay_algebraic(96, 0.1, 0.1, 32);
    let c0 = Matrix::randn(96, 96, 33);
    let got = engine.multiply_axpby(2.0, &a, &x, 0.0, -1.0, &c0).unwrap();
    let mut want = a.matmul(&x).unwrap();
    for (w, &cv) in want.data_mut().iter_mut().zip(c0.data()) {
        *w = 2.0 * *w - cv;
    }
    assert!(got.error_fnorm(&want).unwrap() / want.fnorm() < 1e-5);
}

#[test]
fn axpby_shape_mismatch_rejected() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::zeros(64, 64);
    let c = Matrix::zeros(32, 32);
    assert!(engine.multiply_axpby(1.0, &a, &a, 0.0, 1.0, &c).is_err());
}

#[test]
fn summa_matches_row_coordinator() {
    let b = bundle();
    let a = Matrix::decay_exponential(256, 1.0, 0.6, 41);
    let x = Matrix::decay_exponential(256, 1.0, 0.6, 42);
    let mut cfg = SpammConfig::default();
    cfg.devices = 4;
    let row = Coordinator::new(&b, cfg.clone()).unwrap();
    let tuned = row.tune_tau(&a, &x, 0.3).unwrap();
    let want = row.multiply(&a, &x, tuned.tau).unwrap();
    let summa = SummaCoordinator::new(&b, cfg).unwrap();
    assert_eq!(summa.grid(), (2, 2));
    let (rep, grid_comm, rows_comm) = summa.multiply(&a, &x, tuned.tau).unwrap();
    assert!(rep.c.error_fnorm(&want.c).unwrap() < 1e-6);
    // 2×2 grid halves the per-device B traffic vs full broadcast.
    assert!(grid_comm.b_bytes_per_device < rows_comm.b_bytes_per_device);
    assert!(grid_comm.total_bytes < rows_comm.total_bytes);
}

#[test]
fn power_chain_on_runtime() {
    let b = bundle();
    let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 43);
    let exact = a.matmul(&a).unwrap().matmul(&a).unwrap();
    let r = spamm_power(&coord, &a, 3, 1e-6).unwrap();
    let rel = r.value.error_fnorm(&exact).unwrap() / exact.fnorm().max(1e-30);
    assert!(rel < 1e-3, "rel {rel}");
    assert_eq!(r.steps.len(), 2);
    assert!(r.steps.iter().all(|s| s.wall_secs >= 0.0));
}

#[test]
fn apriori_bound_holds_on_runtime_path() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(256, 1.0, 0.55, 44);
    let x = Matrix::decay_exponential(256, 1.0, 0.55, 45);
    let exact = engine.multiply(&a, &x, 0.0).unwrap();
    let na = normmap(&PaddedMatrix::new(&a, b.lonum));
    let nb = normmap(&PaddedMatrix::new(&x, b.lonum));
    for tau in [1e-4f32, 1e-2] {
        let c = engine.multiply(&a, &x, tau).unwrap();
        let err = exact.error_fnorm(&c).unwrap();
        let bound = apriori_error_bound(&na, &nb, tau).unwrap();
        assert!(err <= bound + 1e-3, "τ={tau}: {err} > {bound}");
    }
}

// ---- failure injection ----------------------------------------------------

#[test]
fn corrupt_hlo_file_fails_cleanly() {
    let b = bundle();
    // Copy the bundle dir metadata but point one artifact at garbage.
    let dir = std::env::temp_dir().join("cuspamm_corrupt_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule bad\nthis is not hlo").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"lonum": 32, "artifacts": [{"name": "dense_n8_f32", "kind": "dense",
            "file": "bad.hlo.txt", "n_outputs": 1,
            "inputs": [{"shape": [8, 8], "dtype": "f32"}],
            "params": {"n": 8, "precision": "f32"}}]}"#,
    )
    .unwrap();
    let corrupt = ArtifactBundle::load(&dir).unwrap();
    let rt = Runtime::new(&corrupt).unwrap();
    let m = Matrix::zeros(8, 8);
    let err = rt.dense(&m, &m, "f32");
    assert!(err.is_err(), "corrupt HLO must fail, not crash");
    drop(b);
}

#[test]
fn wrong_shape_input_fails_cleanly() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    // dense_n256 artifact fed 128×128 inputs → compile/execute error, not UB.
    let m = Matrix::zeros(128, 128);
    let r = rt.execute(
        "dense_n256_f32",
        &[
            cuspamm::runtime::literal::literal_f32(&[128, 128], m.data()).unwrap(),
            cuspamm::runtime::literal::literal_f32(&[128, 128], m.data()).unwrap(),
        ],
    );
    assert!(r.is_err());
}

#[test]
fn missing_artifact_name_fails_cleanly() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    assert!(rt.execute("no_such_artifact", &[]).is_err());
}

#[test]
fn engine_rejects_invalid_config() {
    let b = bundle();
    let mut cfg = SpammConfig::default();
    cfg.lonum = 0;
    assert!(SpammEngine::new(&b, cfg).is_err());
    let mut cfg = SpammConfig::default();
    cfg.devices = 0;
    assert!(Coordinator::new(&b, cfg).is_err());
}

#[test]
fn empty_matrices_handled() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let z = Matrix::zeros(64, 64);
    let (c, stats) = engine.multiply_with_stats(&z, &z, 0.0).unwrap();
    assert_eq!(c.fnorm(), 0.0);
    assert_eq!(stats.valid_products, stats.total_products); // 0 ≥ τ=0 passes
    let (c, stats) = engine.multiply_with_stats(&z, &z, 1.0).unwrap();
    assert_eq!(c.fnorm(), 0.0);
    assert_eq!(stats.valid_products, 0);
}
