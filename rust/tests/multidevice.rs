//! Cross-device conformance harness: every public execution path must be
//! **bitwise identical** across device counts {1, 2, 4} and balance
//! policies {RowBlock, Strided, ResidencyAware} — partitioning assigns
//! each output tile to exactly one device and per-tile accumulation
//! order is fixed by the schedule, so the partition must never change a
//! single bit.  Covered paths: `Coordinator::multiply`, prepared-plan
//! session submits, and expression graphs (power + purification),
//! including tiny-budget eviction pressure and `--no-residency`.

mod common;

use cuspamm::config::{Balance, SpammConfig};
use cuspamm::coordinator::{Approx, Coordinator, SpammSession};
use cuspamm::matrix::Matrix;
use cuspamm::spamm::power::spamm_power;
use cuspamm::spamm::purification::{initial_density, mcweeny_purify};
use cuspamm::util::prng::Rng;

use common::bundle;

const DEVICES: [usize; 3] = [1, 2, 4];
const POLICIES: [Balance; 3] = [
    Balance::RowBlock,
    Balance::Strided(2),
    Balance::ResidencyAware,
];

fn cfg_with(devices: usize, balance: Balance) -> SpammConfig {
    let mut cfg = SpammConfig::default();
    cfg.devices = devices;
    cfg.balance = balance;
    cfg
}

#[test]
fn multiply_is_bitwise_identical_across_devices_and_policies() {
    let b = bundle();
    let a = Matrix::decay_exponential(192, 1.0, 0.5, 31);
    let x = Matrix::decay_exponential(192, 1.0, 0.5, 32);
    let tau = 1e-4f32;
    let reference = Coordinator::new(&b, cfg_with(1, Balance::RowBlock))
        .unwrap()
        .multiply(&a, &x, tau)
        .unwrap();
    for devices in DEVICES {
        for policy in POLICIES {
            let coord = Coordinator::new(&b, cfg_with(devices, policy)).unwrap();
            let rep = coord.multiply(&a, &x, tau).unwrap();
            assert_eq!(
                rep.c.data(),
                reference.c.data(),
                "multiply diverged at devices={devices} policy={policy:?}"
            );
            // A second multiply on the now-warm pools must not change
            // bits either (the residency-aware policy re-partitions
            // against warm views here).
            let warm = coord.multiply(&a, &x, tau).unwrap();
            assert_eq!(
                warm.c.data(),
                reference.c.data(),
                "warm multiply diverged at devices={devices} policy={policy:?}"
            );
            assert_eq!(rep.device_transfer_bytes.len(), devices);
            assert_eq!(rep.device_cross_bytes.len(), devices);
        }
    }
}

#[test]
fn session_prepared_plans_are_bitwise_identical_across_devices_and_policies() {
    let b = bundle();
    let a = Matrix::decay_exponential(160, 1.0, 0.5, 33);
    let x = Matrix::decay_exponential(160, 1.0, 0.5, 34);
    let tau = 1e-4f32;
    let reference = Coordinator::new(&b, cfg_with(1, Balance::RowBlock))
        .unwrap()
        .multiply(&a, &x, tau)
        .unwrap();
    for devices in DEVICES {
        for policy in POLICIES {
            let s = SpammSession::new(&b, cfg_with(devices, policy)).unwrap();
            let ida = s.put(&a).unwrap();
            let idx = s.put(&x).unwrap();
            let plan = s.prepare(ida, idx, Approx::Tau(tau)).unwrap();
            // Two submits: the second rides warm pools and caches.
            let t1 = s.submit(plan).unwrap();
            let t2 = s.submit(plan).unwrap();
            let cold = s.wait(t1).unwrap();
            let warm = s.wait(t2).unwrap();
            for (tag, c) in [("cold", &cold), ("warm", &warm)] {
                assert_eq!(
                    c.c.data(),
                    reference.c.data(),
                    "session {tag} submit diverged at devices={devices} policy={policy:?}"
                );
            }
        }
    }
}

#[test]
fn expr_power_and_purify_are_bitwise_identical_across_devices_and_policies() {
    let b = bundle();
    let a = Matrix::decay_exponential(160, 1.0, 0.5, 35);
    let p0 = initial_density(128, 36);
    let tau = 1e-5f32;
    let ref_power = spamm_power(
        &Coordinator::new(&b, cfg_with(1, Balance::RowBlock)).unwrap(),
        &a,
        4,
        tau,
    )
    .unwrap()
    .value
    .into_owned();
    let ref_purify = mcweeny_purify(
        &Coordinator::new(&b, cfg_with(1, Balance::RowBlock)).unwrap(),
        &p0,
        tau,
        3,
        0.0,
    )
    .unwrap()
    .p;
    for devices in DEVICES {
        for policy in POLICIES {
            let coord = Coordinator::new(&b, cfg_with(devices, policy)).unwrap();
            let power = spamm_power(&coord, &a, 4, tau).unwrap();
            assert_eq!(
                power.value.data(),
                ref_power.data(),
                "expr power diverged at devices={devices} policy={policy:?}"
            );
            let purify = mcweeny_purify(&coord, &p0, tau, 3, 0.0).unwrap();
            assert_eq!(
                purify.p.data(),
                ref_purify.data(),
                "expr purify diverged at devices={devices} policy={policy:?}"
            );
        }
    }
}

#[test]
fn expr_fans_out_to_every_device() {
    // τ = 0 (full schedules): with more tiles than devices, every device
    // must report nonzero tile products for an expression chain.
    let b = bundle();
    let a = Matrix::decay_exponential(160, 1.0, 0.5, 37); // 5x5 tiles
    for devices in [2usize, 4] {
        for policy in POLICIES {
            let coord = Coordinator::new(&b, cfg_with(devices, policy)).unwrap();
            use cuspamm::coordinator::{ExprGraph, ExprSource};
            let mut g = ExprGraph::new();
            let leaf = g.operand();
            let p2 = g.spamm(leaf, leaf, Approx::Tau(0.0));
            let p3 = g.spamm(p2, leaf, Approx::Tau(0.0));
            g.output(p3);
            let plan = coord.prepare_expr(&g, &[ExprSource::Host(&a)]).unwrap();
            let rep = coord.execute_expr(&plan).unwrap();
            assert_eq!(rep.device_products.len(), devices);
            assert!(
                rep.device_products.iter().all(|&p| p > 0),
                "idle device at devices={devices} policy={policy:?}: {:?}",
                rep.device_products
            );
        }
    }
}

#[test]
fn tiny_budget_eviction_pressure_keeps_results_identical() {
    let b = bundle();
    let a = Matrix::decay_exponential(160, 1.0, 0.5, 38);
    let x = Matrix::decay_exponential(160, 1.0, 0.5, 39);
    let tau = 1e-4f32;
    let reference = Coordinator::new(&b, cfg_with(1, Balance::RowBlock))
        .unwrap()
        .multiply(&a, &x, tau)
        .unwrap();
    for devices in DEVICES {
        for policy in POLICIES {
            let mut cfg = cfg_with(devices, policy);
            // Room for two tiles per device: constant eviction churn.
            cfg.device_mem_budget = 2 * 32 * 32 * 4;
            let coord = Coordinator::new(&b, cfg).unwrap();
            let rep = coord.multiply(&a, &x, tau).unwrap();
            assert_eq!(
                rep.c.data(),
                reference.c.data(),
                "tiny-budget multiply diverged at devices={devices} policy={policy:?}"
            );
            assert!(
                rep.stage.residency_evictions > 0,
                "a two-tile budget must actually evict (devices={devices})"
            );
            // Expression chain under the same pressure.
            let power = spamm_power(&coord, &a, 3, tau).unwrap();
            let ref_power = spamm_power(
                &Coordinator::new(&b, cfg_with(1, Balance::RowBlock)).unwrap(),
                &a,
                3,
                tau,
            )
            .unwrap();
            assert_eq!(
                power.value.data(),
                ref_power.value.data(),
                "tiny-budget expr power diverged at devices={devices} policy={policy:?}"
            );
        }
    }
}

#[test]
fn no_residency_keeps_results_identical() {
    let b = bundle();
    let a = Matrix::decay_exponential(160, 1.0, 0.5, 40);
    let x = Matrix::decay_exponential(160, 1.0, 0.5, 41);
    let tau = 1e-4f32;
    let reference = Coordinator::new(&b, cfg_with(1, Balance::RowBlock))
        .unwrap()
        .multiply(&a, &x, tau)
        .unwrap();
    for devices in DEVICES {
        for policy in POLICIES {
            let mut cfg = cfg_with(devices, policy);
            cfg.residency_enabled = false; // residency-aware falls back
            let coord = Coordinator::new(&b, cfg).unwrap();
            let rep = coord.multiply(&a, &x, tau).unwrap();
            assert_eq!(
                rep.c.data(),
                reference.c.data(),
                "--no-residency multiply diverged at devices={devices} policy={policy:?}"
            );
            assert_eq!(rep.stage.residency_hits, 0);
            let power = spamm_power(&coord, &a, 3, tau).unwrap();
            let ref_power = spamm_power(
                &Coordinator::new(&b, cfg_with(1, Balance::RowBlock)).unwrap(),
                &a,
                3,
                tau,
            )
            .unwrap();
            assert_eq!(
                power.value.data(),
                ref_power.value.data(),
                "--no-residency expr power diverged at devices={devices} policy={policy:?}"
            );
        }
    }
}

#[test]
fn more_devices_than_tiles_execute_everywhere() {
    // Regression: a 64×64 matrix is a 2×2 tile grid; 8 devices leave six
    // workers with zero batches, which the executor must tolerate on
    // every path.
    let b = bundle();
    let a = Matrix::decay_exponential(64, 1.0, 0.5, 42);
    let x = Matrix::decay_exponential(64, 1.0, 0.5, 43);
    let reference = Coordinator::new(&b, cfg_with(1, Balance::RowBlock))
        .unwrap()
        .multiply(&a, &x, 0.0)
        .unwrap();
    for policy in POLICIES {
        let coord = Coordinator::new(&b, cfg_with(8, policy)).unwrap();
        let rep = coord.multiply(&a, &x, 0.0).unwrap();
        assert_eq!(
            rep.c.data(),
            reference.c.data(),
            "devices>tiles multiply diverged at policy={policy:?}"
        );
        // The expression path tolerates idle devices too.
        let power = spamm_power(&coord, &a, 3, 0.0).unwrap();
        let ref_power = spamm_power(
            &Coordinator::new(&b, cfg_with(1, Balance::RowBlock)).unwrap(),
            &a,
            3,
            0.0,
        )
        .unwrap();
        assert_eq!(power.value.data(), ref_power.value.data());
    }
}

/// Low-density, high-norm workload: every `lonum`-sized tile holds
/// `spikes` large entries at seeded positions, so τ never prunes a tile
/// yet every tile sits far below any reasonable density threshold — the
/// regime where the adaptive executor routes everything off the dense
/// path.
fn scattered(n: usize, lonum: usize, spikes: usize, seed: u64) -> Matrix {
    let mut m = Matrix::zeros(n, n);
    let mut rng = Rng::new(seed);
    let tiles = n.div_ceil(lonum);
    for ti in 0..tiles {
        for tj in 0..tiles {
            for _ in 0..spikes {
                let r = (ti * lonum + rng.below(lonum)).min(n - 1);
                let c = (tj * lonum + rng.below(lonum)).min(n - 1);
                let mag = rng.range_f32(0.25, 1.0);
                m[(r, c)] = if rng.next_u64() & 1 == 0 { mag } else { -mag };
            }
        }
    }
    m
}

#[test]
fn density_threshold_zero_is_bitwise_inert_on_every_path() {
    // --density-threshold 0 must reproduce the classic executor exactly:
    // multiply, prepared-plan session submits, and expression graphs all
    // stay bitwise identical to the default config across device counts.
    let b = bundle();
    let a = Matrix::decay_exponential(160, 1.0, 0.5, 51);
    let x = Matrix::decay_exponential(160, 1.0, 0.5, 52);
    let p0 = initial_density(128, 53);
    let tau = 1e-4f32;
    let reference = Coordinator::new(&b, SpammConfig::default())
        .unwrap()
        .multiply(&a, &x, tau)
        .unwrap();
    let ref_power = spamm_power(
        &Coordinator::new(&b, SpammConfig::default()).unwrap(),
        &a,
        3,
        tau,
    )
    .unwrap();
    let ref_purify = mcweeny_purify(
        &Coordinator::new(&b, SpammConfig::default()).unwrap(),
        &p0,
        tau,
        3,
        0.0,
    )
    .unwrap();
    for devices in DEVICES {
        let mut cfg = cfg_with(devices, Balance::RowBlock);
        cfg.density_threshold = 0.0;
        let coord = Coordinator::new(&b, cfg.clone()).unwrap();
        let rep = coord.multiply(&a, &x, tau).unwrap();
        assert_eq!(
            rep.c.data(),
            reference.c.data(),
            "threshold-0 multiply diverged at devices={devices}"
        );
        assert_eq!(
            rep.stage.sparse_products + rep.stage.packed_products,
            0,
            "threshold 0 must never route off the dense path"
        );
        assert_eq!(rep.stage.format_saved_bytes, 0);

        let s = SpammSession::new(&b, cfg.clone()).unwrap();
        let ida = s.put(&a).unwrap();
        let idx = s.put(&x).unwrap();
        let plan = s.prepare(ida, idx, Approx::Tau(tau)).unwrap();
        let done = s.wait(s.submit(plan).unwrap()).unwrap();
        assert_eq!(
            done.c.data(),
            reference.c.data(),
            "threshold-0 session submit diverged at devices={devices}"
        );

        let power = spamm_power(&coord, &a, 3, tau).unwrap();
        assert_eq!(
            power.value.data(),
            ref_power.value.data(),
            "threshold-0 expr power diverged at devices={devices}"
        );
        let purify = mcweeny_purify(&coord, &p0, tau, 3, 0.0).unwrap();
        assert_eq!(
            purify.p.data(),
            ref_purify.p.data(),
            "threshold-0 expr purify diverged at devices={devices}"
        );
    }
}

#[test]
fn mixed_format_multiply_is_bitwise_identical_across_devices() {
    // With formats actually routing (scattered-sparse workload, threshold
    // 0.5), the partition must still never change a bit, and the result
    // must agree with the all-dense executor to f32 accumulation noise.
    let b = bundle();
    let n = 4 * b.lonum;
    let a = scattered(n, b.lonum, 8, 61);
    let x = scattered(n, b.lonum, 8, 62);
    let mut cfg = cfg_with(1, Balance::RowBlock);
    cfg.density_threshold = 0.5;
    let reference = Coordinator::new(&b, cfg.clone())
        .unwrap()
        .multiply(&a, &x, 0.0)
        .unwrap();
    assert!(
        reference.stage.sparse_products + reference.stage.packed_products > 0,
        "scattered workload at threshold 0.5 must route off the dense path"
    );
    assert!(reference.stage.format_saved_bytes > 0);
    let dense = Coordinator::new(&b, cfg_with(1, Balance::RowBlock))
        .unwrap()
        .multiply(&a, &x, 0.0)
        .unwrap();
    let err = reference.c.error_fnorm(&dense.c).unwrap();
    assert!(
        err <= 1e-5 * dense.c.fnorm().max(1.0),
        "mixed-format result drifted from dense executor: rel {err}"
    );
    for devices in DEVICES {
        for policy in POLICIES {
            let mut dcfg = cfg_with(devices, policy);
            dcfg.density_threshold = 0.5;
            let coord = Coordinator::new(&b, dcfg).unwrap();
            let rep = coord.multiply(&a, &x, 0.0).unwrap();
            assert_eq!(
                rep.c.data(),
                reference.c.data(),
                "mixed-format multiply diverged at devices={devices} policy={policy:?}"
            );
            // Format routing is schedule-driven, so the mix is identical
            // on every partition of the same schedule.
            assert_eq!(
                (
                    rep.stage.dense_products,
                    rep.stage.sparse_products,
                    rep.stage.packed_products
                ),
                (
                    reference.stage.dense_products,
                    reference.stage.sparse_products,
                    reference.stage.packed_products
                ),
                "format mix changed with the partition at devices={devices} policy={policy:?}"
            );
        }
    }
}

#[test]
fn mixed_format_session_and_expr_are_bitwise_identical_across_devices() {
    let b = bundle();
    let n = 4 * b.lonum;
    let a = scattered(n, b.lonum, 8, 63);
    let x = scattered(n, b.lonum, 8, 64);
    let mut cfg1 = cfg_with(1, Balance::RowBlock);
    cfg1.density_threshold = 0.5;
    let ref_mul = Coordinator::new(&b, cfg1.clone())
        .unwrap()
        .multiply(&a, &x, 0.0)
        .unwrap();
    let ref_power = spamm_power(&Coordinator::new(&b, cfg1.clone()).unwrap(), &a, 3, 0.0).unwrap();
    for devices in DEVICES {
        let mut cfg = cfg_with(devices, Balance::RowBlock);
        cfg.density_threshold = 0.5;
        let s = SpammSession::new(&b, cfg.clone()).unwrap();
        let ida = s.put(&a).unwrap();
        let idx = s.put(&x).unwrap();
        let plan = s.prepare(ida, idx, Approx::Tau(0.0)).unwrap();
        let cold = s.wait(s.submit(plan).unwrap()).unwrap();
        let warm = s.wait(s.submit(plan).unwrap()).unwrap();
        for (tag, c) in [("cold", &cold), ("warm", &warm)] {
            assert_eq!(
                c.c.data(),
                ref_mul.c.data(),
                "mixed-format session {tag} diverged at devices={devices}"
            );
        }
        let coord = Coordinator::new(&b, cfg).unwrap();
        let power = spamm_power(&coord, &a, 3, 0.0).unwrap();
        assert_eq!(
            power.value.data(),
            ref_power.value.data(),
            "mixed-format expr power diverged at devices={devices}"
        );
    }
}

#[test]
fn session_plans_pin_only_the_devices_that_use_them() {
    // Regression: plan pinning used to hit every pool (and expr pinning
    // only device 0) regardless of where the partition put the work.
    let b = bundle();
    // 2×2 tile grid, 8 devices, RowBlock: only devices 0 and 4 own rows.
    let mut cfg = cfg_with(8, Balance::RowBlock);
    cfg.queue_depth = 8;
    let s = SpammSession::new(&b, cfg).unwrap();
    let a = s.put(&Matrix::decay_exponential(64, 1.0, 0.5, 44)).unwrap();
    let x = s.put(&Matrix::decay_exponential(64, 1.0, 0.5, 45)).unwrap();
    let plan = s.prepare(a, x, Approx::Tau(0.0)).unwrap();
    let pools = s.residency_pools();
    assert_eq!(pools.len(), 8);
    for (d, p) in pools.iter().enumerate() {
        let want = usize::from(d == 0 || d == 4) * 2;
        assert_eq!(
            p.pinned_operands(),
            want,
            "device {d}: multiply plan must pin exactly the owning devices"
        );
    }
    s.release_plan(plan).unwrap();
    for (d, p) in pools.iter().enumerate() {
        assert_eq!(p.pinned_operands(), 0, "device {d}: release must unpin");
    }

    // Expression plans pin every device their placement maps use — not
    // just device 0.
    let two = SpammSession::new(&b, cfg_with(2, Balance::RowBlock)).unwrap();
    let m = two
        .put(&Matrix::decay_exponential(128, 1.0, 0.5, 46))
        .unwrap();
    use cuspamm::coordinator::ExprGraph;
    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let sq = g.spamm(leaf, leaf, Approx::Tau(0.0));
    g.output(sq);
    let eplan = two.prepare_expr(&g, &[m]).unwrap();
    for (d, p) in two.residency_pools().iter().enumerate() {
        assert_eq!(
            p.pinned_operands(),
            1,
            "device {d}: expr plan must pin the leaf in every used pool"
        );
    }
    // The plan still executes correctly with the narrowed pinning.
    let done = two.wait(two.submit_expr(eplan).unwrap()).unwrap();
    assert_eq!(done.c.rows(), 128);
    two.release_expr_plan(eplan).unwrap();
    for p in two.residency_pools() {
        assert_eq!(p.pinned_operands(), 0);
    }
}

#[test]
fn warm_multidevice_submits_never_recompile() {
    // Regression: the multi-device fan-out used to rebuild per-device
    // runtimes (and recompile every kernel) on each request.  With the
    // persistent per-device worker pool, the cold submit pays all the
    // compiles and every warm submit on the same session reports zero —
    // across fan-out widths and both the multiply and expression paths.
    let b = bundle();
    let a = Matrix::decay_exponential(256, 1.0, 0.5, 47);
    let x = Matrix::decay_exponential(256, 1.0, 0.5, 48);
    for devices in [2usize, 4] {
        let s = SpammSession::new(&b, cfg_with(devices, Balance::Strided(devices))).unwrap();
        let ida = s.put(&a).unwrap();
        let idx = s.put(&x).unwrap();
        let plan = s.prepare(ida, idx, Approx::Tau(0.0)).unwrap();
        let cold = s.wait(s.submit(plan).unwrap()).unwrap();
        assert!(
            cold.stats.compiles > 0,
            "devices={devices}: the cold submit pays the kernel compiles"
        );
        let warm = s.wait(s.submit(plan).unwrap()).unwrap();
        assert_eq!(
            warm.stats.compiles,
            0,
            "devices={devices}: a warm submit on resident workers must not recompile"
        );
        assert_eq!(warm.c.data(), cold.c.data());
        // Resubmitting once more stays at zero — the pool's runtimes and
        // their executable caches are session-lifetime, not per-request.
        let third = s.wait(s.submit(plan).unwrap()).unwrap();
        assert_eq!(third.stats.compiles, 0);
    }
}
