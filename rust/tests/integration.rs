//! Integration tests over the real AOT artifacts: every executable the
//! request path uses is loaded, compiled, executed, and checked against the
//! host oracles.  Requires `make artifacts`.

mod common;

use cuspamm::config::{Balance, Precision, SpammConfig};
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::runtime::Runtime;
use cuspamm::spamm::normmap::normmap;
use cuspamm::spamm::reference::spamm_flat_host;
use cuspamm::spamm::tuner::{tune_tau, TuneParams};
use cuspamm::spamm::SpammEngine;

use common::bundle;

fn rel_err(got: &Matrix, want: &Matrix) -> f64 {
    got.error_fnorm(want).unwrap() / want.fnorm().max(1e-30)
}

#[test]
fn dense_artifact_matches_host_matmul() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 1);
    let x = Matrix::decay_algebraic(256, 0.1, 0.1, 2);
    let got = rt.dense(&a, &x, "f32").unwrap();
    let want = a.matmul(&x).unwrap();
    assert!(rel_err(&got, &want) < 1e-5, "rel err {}", rel_err(&got, &want));
}

#[test]
fn dense_bf16_artifact_is_close() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 3);
    let x = Matrix::decay_algebraic(256, 0.1, 0.1, 4);
    let got = rt.dense(&a, &x, "bf16").unwrap();
    let want = a.matmul(&x).unwrap();
    let re = rel_err(&got, &want);
    assert!(re > 1e-7, "bf16 must actually quantize (re={re})");
    assert!(re < 2e-2, "bf16 rel err {re}");
}

#[test]
fn getnorm_artifact_matches_host_normmap() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 5);
    let got = rt.getnorm(&a, b.lonum, false).unwrap();
    let want = normmap(&PaddedMatrix::new(&a, b.lonum));
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()));
    assert!(got.max_abs_diff(&want).unwrap() < 1e-4);
}

#[test]
fn getnorm_mxu_artifact_is_close() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 6);
    let got = rt.getnorm(&a, b.lonum, true).unwrap();
    let want = normmap(&PaddedMatrix::new(&a, b.lonum));
    // bf16 ones-matmul reduction: ~2-3 digits.
    for r in 0..want.rows() {
        for c in 0..want.cols() {
            let w = want[(r, c)];
            assert!((got[(r, c)] - w).abs() <= 0.03 * w.abs() + 1e-4);
        }
    }
}

#[test]
fn tilegemm_artifact_matches_host() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    let l = b.lonum;
    let batch = 7usize;
    let cap = 64usize;
    let ta = Matrix::randn(batch * l, l, 7);
    let tb = Matrix::randn(batch * l, l, 8);
    let mut a_buf = vec![0.0f32; cap * l * l];
    let mut b_buf = vec![0.0f32; cap * l * l];
    a_buf[..batch * l * l].copy_from_slice(ta.data());
    b_buf[..batch * l * l].copy_from_slice(tb.data());
    let out = rt.tile_gemm(&a_buf, &b_buf, cap, l, "f32").unwrap();
    for s in 0..batch {
        let am = Matrix::from_vec(l, l, ta.data()[s * l * l..(s + 1) * l * l].to_vec()).unwrap();
        let bm = Matrix::from_vec(l, l, tb.data()[s * l * l..(s + 1) * l * l].to_vec()).unwrap();
        let want = am.matmul(&bm).unwrap();
        let got = Matrix::from_vec(l, l, out[s * l * l..(s + 1) * l * l].to_vec()).unwrap();
        assert!(rel_err(&got, &want) < 1e-5, "slot {s}");
    }
    // padded tail is exactly zero
    assert!(out[batch * l * l..].iter().all(|&x| x == 0.0));
}

#[test]
fn tune_artifact_agrees_with_host_tuner() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    let a = Matrix::decay_algebraic(512, 0.1, 0.1, 9);
    let x = Matrix::decay_algebraic(512, 0.1, 0.1, 10);
    let na = normmap(&PaddedMatrix::new(&a, b.lonum));
    let nb = normmap(&PaddedMatrix::new(&x, b.lonum));
    let (tau_dev, ratio_dev) = rt.tune(&na, &nb, 0.10).unwrap();
    let host = tune_tau(&na, &nb, 0.10, TuneParams::default()).unwrap();
    assert!((ratio_dev as f64 - 0.10).abs() < 0.02, "device ratio {ratio_dev}");
    assert!((host.achieved_ratio - 0.10).abs() < 0.01);
    // Both τ land in the same decade.
    assert!(
        (tau_dev.ln() - host.tau.ln()).abs() < 1.0,
        "τ device {tau_dev} vs host {}",
        host.tau
    );
}

#[test]
fn spamm_fused_artifact_matches_host_flat() {
    let b = bundle();
    let rt = Runtime::new(&b).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 11);
    let x = Matrix::decay_algebraic(256, 0.1, 0.1, 12);
    let na = normmap(&PaddedMatrix::new(&a, b.lonum));
    let tau = {
        let mut v: Vec<f32> = na.data().to_vec();
        v.sort_by(|p, q| p.partial_cmp(q).unwrap());
        let med = v[v.len() / 2];
        med * med
    };
    let got = rt.spamm_fused(&a, &x, tau, "f32").unwrap();
    let want = spamm_flat_host(&a, &x, tau, b.lonum).unwrap();
    assert!(rel_err(&got, &want) < 1e-5);
}

#[test]
fn engine_tau_zero_equals_dense() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 13);
    let x = Matrix::decay_algebraic(256, 0.1, 0.1, 14);
    let (c, stats) = engine.multiply_with_stats(&a, &x, 0.0).unwrap();
    assert_eq!(stats.valid_products, stats.total_products);
    let want = engine.dense(&a, &x).unwrap();
    assert!(rel_err(&c, &want) < 1e-5);
}

#[test]
fn engine_matches_host_flat_spamm() {
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(256, 1.0, 0.5, 15);
    let x = Matrix::decay_exponential(256, 1.0, 0.5, 16);
    let tuned = engine.tune_tau(&a, &x, 0.25).unwrap();
    let (c, stats) = engine.multiply_with_stats(&a, &x, tuned.tau).unwrap();
    // On strongly decayed matrices the reachable ratios are quantized; the
    // engine must agree with the tuner's *achieved* ratio exactly.
    assert!((stats.valid_ratio - tuned.achieved_ratio).abs() < 1e-9);
    assert!(stats.valid_ratio < 0.9, "τ must actually skip work");
    let want = spamm_flat_host(&a, &x, tuned.tau, b.lonum).unwrap();
    assert!(rel_err(&c, &want) < 1e-5);
}

#[test]
fn engine_skips_work() {
    // Lower valid ratio ⇒ fewer executed products (the whole point).
    let b = bundle();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(512, 1.0, 0.5, 17);
    let x = Matrix::decay_exponential(512, 1.0, 0.5, 18);
    let t10 = engine.tune_tau(&a, &x, 0.10).unwrap();
    let (_, s10) = engine.multiply_with_stats(&a, &x, t10.tau).unwrap();
    let (_, s100) = engine.multiply_with_stats(&a, &x, 0.0).unwrap();
    assert!(s10.valid_products * 8 < s100.valid_products);
}

#[test]
fn engine_bf16_close_to_f32() {
    let b = bundle();
    let mut cfg = SpammConfig::default();
    cfg.precision = Precision::Bf16;
    let bf = SpammEngine::new(&b, cfg).unwrap();
    let ff = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 19);
    let x = Matrix::decay_algebraic(256, 0.1, 0.1, 20);
    let cb = bf.multiply(&a, &x, 0.0).unwrap();
    let cf = ff.multiply(&a, &x, 0.0).unwrap();
    let re = rel_err(&cb, &cf);
    assert!(re > 1e-7 && re < 2e-2, "bf16 rel err {re}");
}

#[test]
fn coordinator_matches_single_device() {
    let b = bundle();
    let a = Matrix::decay_exponential(256, 1.0, 0.55, 21);
    let x = Matrix::decay_exponential(256, 1.0, 0.55, 22);
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let tuned = engine.tune_tau(&a, &x, 0.20).unwrap();
    let want = engine.multiply(&a, &x, tuned.tau).unwrap();
    for devices in [2usize, 4] {
        for balance in [Balance::RowBlock, Balance::Strided(2)] {
            let mut cfg = SpammConfig::default();
            cfg.devices = devices;
            cfg.balance = balance;
            let coord = Coordinator::new(&b, cfg).unwrap();
            let rep = coord.multiply(&a, &x, tuned.tau).unwrap();
            assert!(
                rel_err(&rep.c, &want) < 1e-6,
                "devices={devices} balance={balance:?}"
            );
            assert_eq!(rep.valid_products, rep.device_load.iter().sum::<usize>());
            assert!(rep.imbalance >= 1.0 - 1e-9);
        }
    }
}

#[test]
fn coordinator_rectangular() {
    let b = bundle();
    let a = Matrix::randn(100, 70, 23);
    let x = Matrix::randn(70, 130, 24);
    let mut cfg = SpammConfig::default();
    cfg.devices = 3;
    let coord = Coordinator::new(&b, cfg).unwrap();
    let rep = coord.multiply(&a, &x, 0.0).unwrap();
    let want = a.matmul(&x).unwrap();
    assert_eq!((rep.c.rows(), rep.c.cols()), (100, 130));
    assert!(rel_err(&rep.c, &want) < 1e-5);
}

#[test]
fn device_pool_executes() {
    use cuspamm::runtime::DevicePool;
    let b = bundle();
    let pool = DevicePool::new(&b, 2, 4).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 25);
    let x = Matrix::decay_algebraic(256, 0.1, 0.1, 26);
    let out = pool
        .call(
            1,
            "dense_n256_f32",
            vec![
                (vec![256, 256], a.data().to_vec()),
                (vec![256, 256], x.data().to_vec()),
            ],
        )
        .unwrap();
    let got = Matrix::from_vec(256, 256, out[0].1.clone()).unwrap();
    let want = a.matmul(&x).unwrap();
    assert!(rel_err(&got, &want) < 1e-5);
    assert!(pool.busy_secs()[1] > 0.0);
    assert_eq!(pool.busy_secs()[0], 0.0);
}

#[test]
fn device_pool_staged_buffers_execute_in_place() {
    use cuspamm::runtime::{DevicePool, ExecInput};
    let b = bundle();
    let pool = DevicePool::new(&b, 2, 4).unwrap();
    let a = Matrix::decay_algebraic(256, 0.1, 0.1, 27);
    let x = Matrix::decay_algebraic(256, 0.1, 0.1, 28);
    let want = a.matmul(&x).unwrap();

    // Upload A once; reference the staged buffer across repeated calls
    // mixing resident and per-call inputs.
    let a_buf = pool
        .upload(0, (vec![256, 256], a.data().to_vec()))
        .unwrap();
    for _ in 0..2 {
        let out = pool
            .call_inputs(
                0,
                "dense_n256_f32",
                vec![
                    ExecInput::Buffer(a_buf),
                    ExecInput::Host((vec![256, 256], x.data().to_vec())),
                ],
            )
            .unwrap();
        let got = Matrix::from_vec(256, 256, out[0].1.clone()).unwrap();
        assert!(rel_err(&got, &want) < 1e-5);
    }
    // Upload time is a transfer, not busy time.
    assert!(pool.transfer_secs()[0] > 0.0);
    assert_eq!(pool.transfer_secs()[1], 0.0);

    // Buffers are device-scoped: device 1 must reject device 0's handle.
    assert!(pool
        .call_inputs(1, "dense_n256_f32", vec![
            ExecInput::Buffer(a_buf),
            ExecInput::Host((vec![256, 256], x.data().to_vec())),
        ])
        .is_err());

    // Freed buffers are gone (the handle routes the free to its device).
    pool.free(a_buf).unwrap();
    assert!(pool
        .call_inputs(0, "dense_n256_f32", vec![
            ExecInput::Buffer(a_buf),
            ExecInput::Host((vec![256, 256], x.data().to_vec())),
        ])
        .is_err());
}

#[test]
fn cnn_loads_and_matches_buildtime_accuracy() {
    let b = bundle();
    // The hostsim bundle now synthesizes-and-freezes a deterministic CNN
    // fixture (weights + frozen test set + recorded accuracy), so this
    // path runs without the python/JAX toolchain.  A real AOT bundle
    // that predates its CNN export still skips gracefully.
    let Some(meta) = b.cnn.clone() else {
        eprintln!("SKIPPED cnn_loads_and_matches_buildtime_accuracy: no CNN export in bundle");
        return;
    };
    let cnn = cuspamm::cnn::Cnn::load(&meta).unwrap();
    let modes = std::collections::BTreeMap::new();
    // Host path over a subset; must be near the recorded build-time value.
    let acc = cnn.accuracy(&modes, None, 100, Some(200)).unwrap();
    assert!(
        (acc - meta.test_accuracy).abs() < 0.06,
        "rust acc {acc} vs build-time {}",
        meta.test_accuracy
    );
}

#[test]
fn cnn_spamm_tau_zero_preserves_accuracy() {
    let b = bundle();
    // Runs against the frozen hostsim fixture (margin-filtered labels,
    // so τ = 0's reordering-level numeric differences cannot flip an
    // argmax); skips only for a real bundle without a CNN export.
    let Some(meta) = b.cnn.clone() else {
        eprintln!("SKIPPED cnn_spamm_tau_zero_preserves_accuracy: no CNN export in bundle");
        return;
    };
    let cnn = cuspamm::cnn::Cnn::load(&meta).unwrap();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let mut modes = std::collections::BTreeMap::new();
    let base = cnn.accuracy(&modes, Some(&engine), 100, Some(100)).unwrap();
    modes.insert("conv2".to_string(), cuspamm::cnn::GemmMode::Spamm { tau: 0.0 });
    let with0 = cnn.accuracy(&modes, Some(&engine), 100, Some(100)).unwrap();
    assert_eq!(base, with0);
}

#[test]
fn cnn_tau_sweep_degrades_monotonically_from_fixture_accuracy() {
    // The Table 5 shape: as τ grows, a substituted conv layer prunes
    // more products and end-task accuracy can only stay or drop from
    // the frozen fixture's recorded value.
    let b = bundle();
    let Some(meta) = b.cnn.clone() else {
        eprintln!("SKIPPED cnn_tau_sweep: no CNN export in bundle");
        return;
    };
    let cnn = cuspamm::cnn::Cnn::load(&meta).unwrap();
    let engine = SpammEngine::new(&b, SpammConfig::default()).unwrap();
    let mut modes = std::collections::BTreeMap::new();
    modes.insert("conv2".to_string(), cuspamm::cnn::GemmMode::Spamm { tau: 0.0 });
    let exact = cnn.accuracy(&modes, Some(&engine), 100, None).unwrap();
    assert_eq!(exact, meta.test_accuracy, "τ=0 must reproduce the fixture");
    // A τ far beyond every tile-norm product prunes the whole layer; the
    // network degrades (or, degenerately, ties) but never improves.
    modes.insert(
        "conv2".to_string(),
        cuspamm::cnn::GemmMode::Spamm { tau: 1e6 },
    );
    let pruned = cnn.accuracy(&modes, Some(&engine), 100, None).unwrap();
    assert!(
        pruned <= exact,
        "pruning conv2 entirely cannot beat the exact layer: {pruned} > {exact}"
    );
}
