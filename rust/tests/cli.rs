//! CLI integration: run the built `cuspamm` binary end-to-end (the
//! launcher a downstream user actually touches).

use std::process::Command;

fn bin() -> std::path::PathBuf {
    // cargo test binaries live in target/<profile>/deps; the CLI binary is
    // one level up.
    let mut p = std::env::current_exe().unwrap();
    p.pop();
    if p.ends_with("deps") {
        p.pop();
    }
    p.join("cuspamm")
}

fn artifacts_dir() -> Option<&'static str> {
    for c in ["artifacts", "../artifacts"] {
        if std::path::Path::new(c).join("manifest.json").exists() {
            return Some(c);
        }
    }
    None
}

#[test]
fn info_lists_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let out = Command::new(bin())
        .args(["info", "--artifacts", dir])
        .output()
        .expect("spawn cuspamm (cargo build first)");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("LoNum"));
    assert!(stdout.contains("dense_n1024_f32"));
    assert!(stdout.contains("cnn:"));
}

#[test]
fn tune_reports_tau() {
    let Some(dir) = artifacts_dir() else { return };
    let out = Command::new(bin())
        .args(["tune", "--artifacts", dir, "--n", "256", "--ratio", "0.2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("τ ="), "{stdout}");
    assert!(stdout.contains("ratio ="));
}

#[test]
fn run_reports_speedup_and_error() {
    let Some(dir) = artifacts_dir() else { return };
    let out = Command::new(bin())
        .args([
            "run", "--artifacts", dir, "--n", "256", "--ratio", "0.1",
            "--devices", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("speedup"), "{stdout}");
    assert!(stdout.contains("‖E‖_F"));
}

#[test]
fn unknown_subcommand_fails_with_hint() {
    let out = Command::new(bin()).arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn help_exits_zero() {
    let out = Command::new(bin()).arg("help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("subcommands"));
}

#[test]
fn bad_option_is_a_config_error() {
    let out = Command::new(bin())
        .args(["run", "--bogus-flag", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2)); // config errors exit 2
}
