//! Integration tests for the `SpammSession` front-end: registered
//! operands, prepared plans, the async ticketed queue, and the legacy
//! `SpammService` shim.

mod common;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, Coordinator, Priority, SpammSession};
use cuspamm::matrix::Matrix;

use common::bundle;

fn session(cfg: SpammConfig) -> SpammSession {
    SpammSession::new(&bundle(), cfg).unwrap()
}

#[test]
fn put_dedups_identical_content() {
    let s = session(SpammConfig::default());
    let m = Matrix::decay_algebraic(96, 0.1, 0.1, 11);
    let a = s.put(&m).unwrap();
    // Identical content, independently generated.
    let b = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 11)).unwrap();
    assert_eq!(a, b, "two puts of identical data must share one entry");
    let stats = s.store_stats();
    assert_eq!(stats.puts, 2);
    assert_eq!(stats.dedup_hits, 1);
    assert_eq!(stats.resident_operands, 1);
    // Two refs: both releases succeed, a third errors.
    s.release(a).unwrap();
    s.release(b).unwrap();
    assert!(s.release(a).is_err());
    // Different content is a different entry.
    let c = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 12)).unwrap();
    assert_ne!(a, c);
}

#[test]
fn store_eviction_spares_plan_pinned_operands() {
    let n = 64usize;
    let bytes = n * n * 4; // n is a lonum multiple: padded == logical
    let mut cfg = SpammConfig::default();
    cfg.store_budget = bytes; // room for a single operand
    let s = session(cfg);
    let a = s.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 1)).unwrap();
    let plan = s.prepare(a, a, Approx::Tau(1e-4)).unwrap();
    s.release(a).unwrap();
    // Churn: registered-and-released operands blow through the budget...
    for seed in 10..14u64 {
        let x = s.put(&Matrix::decay_algebraic(n, 0.1, 0.1, seed)).unwrap();
        s.release(x).unwrap();
    }
    assert!(s.store_stats().evictions >= 3, "churn must evict");
    // ...but the plan-pinned operand survives: preparing against it still
    // resolves (an evicted handle would error), and the plan still runs.
    let t = s.submit(plan).unwrap();
    let done = s.wait(t).unwrap();
    assert_eq!(done.c.rows(), n);
    // Release the plan: the operand unpins and budget pressure may now
    // evict it.
    s.release_plan(plan).unwrap();
    let x = s.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 99)).unwrap();
    assert!(
        s.prepare(a, x, Approx::Tau(1e-4)).is_err(),
        "unpinned released operand should have been evicted by now"
    );
}

#[test]
fn tickets_complete_out_of_order_with_priorities() {
    let s = session(SpammConfig::default());
    // A hefty head-of-line job keeps the worker busy while the small
    // low/high pair is queued behind it.
    let big = s.put(&Matrix::decay_algebraic(512, 0.1, 0.1, 2)).unwrap();
    let small = s.put(&Matrix::decay_algebraic(128, 0.1, 0.1, 3)).unwrap();
    let p_big = s.prepare(big, big, Approx::ValidRatio(0.3)).unwrap();
    let p_small = s.prepare(small, small, Approx::Tau(1e-5)).unwrap();
    let t_head = s.submit(p_big).unwrap();
    let t_low = s.submit_with(p_small, Priority::Low).unwrap();
    let t_high = s.submit_with(p_small, Priority::High).unwrap();
    // Out-of-order retrieval: redeem the last ticket first.
    let high = s.wait(t_high).unwrap();
    let low = s.wait(t_low).unwrap();
    let head = s.wait(t_head).unwrap();
    assert_eq!(head.c.rows(), 512);
    assert_eq!(high.priority, Priority::High);
    // Both were queued while the head job ran; the high-priority one must
    // have been dequeued first, so it spent less time waiting.
    assert!(
        high.latency_secs <= low.latency_secs,
        "high {:.6}s vs low {:.6}s",
        high.latency_secs,
        low.latency_secs
    );
}

#[test]
fn admission_queue_is_bounded() {
    let mut cfg = SpammConfig::default();
    cfg.queue_depth = 1;
    let s = session(cfg);
    let big = s.put(&Matrix::decay_algebraic(512, 0.1, 0.1, 4)).unwrap();
    let plan = s.prepare(big, big, Approx::ValidRatio(0.3)).unwrap();
    let _head = s.submit(plan).unwrap();
    // The worker needs a moment to dequeue the head job; retry until the
    // depth-1 window admits the second submit, then the third must be
    // rejected while the (long) head job still runs.
    let _queued = loop {
        match s.submit(plan) {
            Ok(t) => break t,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(1)),
        }
    };
    let overflow = s.submit(plan);
    assert!(
        overflow.is_err(),
        "third submit must hit the depth-1 admission bound"
    );
    let done = s.wait_all().unwrap();
    assert_eq!(done.len(), 2);
}

#[test]
fn repeated_operand_trace_shows_warm_plan_reuse() {
    // The acceptance trace: one registered A across 8 multiplies of one
    // prepared plan.
    const REPEATS: usize = 8;
    let s = session(SpammConfig::default());
    let a = s.put(&Matrix::decay_algebraic(256, 0.1, 0.1, 7)).unwrap();
    let plan = s.prepare(a, a, Approx::ValidRatio(0.1)).unwrap();
    let tickets: Vec<_> = (0..REPEATS).map(|_| s.submit(plan).unwrap()).collect();
    let jobs: Vec<_> = tickets.into_iter().map(|t| s.wait(t).unwrap()).collect();
    assert_eq!(jobs.len(), REPEATS);

    // Cold job: charged the prepare phases (normmaps + tuning +
    // scheduling) and the operand upload.
    let cold = &jobs[0];
    assert!(cold.stats.norm_secs > 0.0, "cold job must carry norm phase");
    assert!(cold.stats.schedule_secs > 0.0);
    assert!(cold.stats.transfer_bytes > 0, "cold job uploads tiles");

    // Warm jobs: front phases skipped entirely, zero operand bytes
    // moved, every tile a residency hit.
    for (i, c) in jobs.iter().enumerate().skip(1) {
        assert_eq!(c.stats.norm_secs, 0.0, "warm job {i} recomputed norms");
        assert_eq!(c.stats.schedule_secs, 0.0, "warm job {i} rescheduled");
        assert_eq!(c.stats.transfer_bytes, 0, "warm job {i} uploaded bytes");
        assert!(c.stats.residency_hits > 0, "warm job {i} missed the pool");
        assert!(
            c.stats.transfer_saved_bytes > 0,
            "warm job {i} must report saved transfers"
        );
    }
    // All eight results are bitwise identical to each other and to the
    // one-shot coordinator path at the same τ.
    let coord = Coordinator::new(&bundle(), SpammConfig::default()).unwrap();
    let reference = coord
        .multiply(
            &Matrix::decay_algebraic(256, 0.1, 0.1, 7),
            &Matrix::decay_algebraic(256, 0.1, 0.1, 7),
            cold.tau,
        )
        .unwrap();
    for c in &jobs {
        assert_eq!(c.c.data(), reference.c.data());
    }
    // The warm speedup itself is asserted in `serve --smoke` (a timing
    // claim has no place in a unit test); here just record that cold did
    // strictly more work.
    let warm_min = jobs[1..]
        .iter()
        .map(|c| c.compute_secs)
        .fold(f64::MAX, f64::min);
    println!(
        "cold {:.5}s vs warm min {:.5}s ({:.2}x)",
        cold.compute_secs,
        warm_min,
        cold.compute_secs / warm_min.max(1e-12)
    );
}

#[test]
fn prepare_dedups_plans_and_validates() {
    let s = session(SpammConfig::default());
    let a = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 21)).unwrap();
    let b = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 22)).unwrap();
    let p1 = s.prepare(a, b, Approx::Tau(1e-4)).unwrap();
    let p2 = s.prepare(a, b, Approx::Tau(1e-4)).unwrap();
    assert_eq!(p1, p2, "identical (a, b, approx) must share a plan");
    let p3 = s.prepare(a, b, Approx::Tau(1e-3)).unwrap();
    assert_ne!(p1, p3);
    // Shape and target validation.
    let rect = s.put(&Matrix::randn(96, 64, 23)).unwrap();
    assert!(s.prepare(rect, rect, Approx::Tau(1e-4)).is_err(), "64 ≠ 96");
    assert!(s.prepare(a, b, Approx::ValidRatio(0.0)).is_err());
    assert!(s.prepare(a, b, Approx::Tau(-1.0)).is_err());
    // Rectangular chains with agreeing inner dims are fine.
    let tall = s.put(&Matrix::randn(64, 96, 24)).unwrap();
    let plan = s.prepare(tall, rect, Approx::Tau(0.0)).unwrap();
    let (_, rows, cols) = s.plan_info(plan).unwrap();
    assert_eq!((rows, cols), (64, 64));
    let done = s.wait(s.submit(plan).unwrap()).unwrap();
    assert_eq!((done.c.rows(), done.c.cols()), (64, 64));
}

#[test]
fn released_plan_rejects_submit() {
    let s = session(SpammConfig::default());
    let a = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 31)).unwrap();
    let plan = s.prepare(a, a, Approx::Tau(1e-4)).unwrap();
    s.release_plan(plan).unwrap();
    assert!(s.submit(plan).is_err());
    assert!(s.release_plan(plan).is_err(), "double release");
}

#[test]
fn wait_on_bogus_ticket_errors_when_idle() {
    let s = session(SpammConfig::default());
    let a = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 41)).unwrap();
    let plan = s.prepare(a, a, Approx::Tau(1e-4)).unwrap();
    let t = s.submit(plan).unwrap();
    let done = s.wait(t).unwrap();
    // Re-waiting a redeemed ticket errors instead of hanging.
    assert!(s.wait(t).is_err());
    assert_eq!(done.ticket, t);
}

#[test]
#[allow(deprecated)]
fn shim_and_session_agree_bitwise_on_the_same_trace() {
    use cuspamm::coordinator::service::{synthetic_trace, SpammService};

    let trace = synthetic_trace(4, 96, 5);
    // Legacy path: the deprecated shim.
    let mut svc = SpammService::new(&bundle(), SpammConfig::default()).unwrap();
    for (a, b, ap) in synthetic_trace(4, 96, 5) {
        svc.submit(a, b, ap);
    }
    let (legacy, stats) = svc.drain().unwrap();
    assert_eq!(stats.completed, 4);
    assert!(stats.latency.is_some());

    // Session path: register, prepare, submit, wait.
    let s = session(SpammConfig::default());
    for ((a, b, ap), old) in trace.into_iter().zip(&legacy) {
        let (ida, idb) = (s.put(&a).unwrap(), s.put(&b).unwrap());
        let t = s.submit_once(ida, idb, ap).unwrap();
        let done = s.wait(t).unwrap();
        assert_eq!(
            done.c.data(),
            old.c.data(),
            "session and shim must be bitwise identical"
        );
        assert_eq!(done.tau.to_bits(), old.tau.to_bits(), "τ resolution must agree");
    }
}

#[test]
fn wait_all_returns_ticket_order() {
    let s = session(SpammConfig::default());
    let a = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 51)).unwrap();
    let b = s.put(&Matrix::decay_algebraic(96, 0.1, 0.1, 52)).unwrap();
    let p1 = s.prepare(a, a, Approx::Tau(1e-4)).unwrap();
    let p2 = s.prepare(a, b, Approx::Tau(1e-4)).unwrap();
    let t1 = s.submit_with(p1, Priority::Low).unwrap();
    let t2 = s.submit_with(p2, Priority::High).unwrap();
    let done = s.wait_all().unwrap();
    assert_eq!(done.len(), 2);
    // Returned in ticket order regardless of execution order.
    assert_eq!(done[0].ticket, t1);
    assert_eq!(done[1].ticket, t2);
    assert_eq!(s.pending(), 0);
}
