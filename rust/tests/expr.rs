//! Integration tests for the expression-graph API: device-resident
//! intermediates, norm propagation, retirement eviction, warm re-submits,
//! and the session's expr ticket path.
//!
//! The headline bitwise-identity tests (expr vs loop for `spamm_power`
//! and `mcweeny_purify` at τ = 0 and τ > 0) live next to the wrappers in
//! `src/spamm/{power,purification}.rs`; here the API itself is exercised.

mod common;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, Coordinator, ExprGraph, ExprSource, SpammSession};
use cuspamm::matrix::Matrix;
use cuspamm::spamm::power::{spamm_power, spamm_power_loop};

use common::bundle;

fn coord(cfg: SpammConfig) -> Coordinator {
    Coordinator::new(&bundle(), cfg).unwrap()
}

/// A^4 as one graph: A² and A³ are interior intermediates, A⁴ the root.
fn power4_graph(tau: f32) -> ExprGraph {
    let mut g = ExprGraph::new();
    let a = g.operand();
    let mut cur = a;
    for _ in 0..3 {
        cur = g.spamm(cur, a, Approx::Tau(tau));
    }
    g.output(cur);
    g
}

#[test]
fn intermediates_transfer_zero_bytes_and_are_freed_at_retirement() {
    let c = coord(SpammConfig::default());
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 21);
    let g = power4_graph(1e-5);
    let plan = c.prepare_expr(&g, &[ExprSource::Host(&a)]).unwrap();
    let rep = c.execute_expr(&plan).unwrap();

    // Every uploaded byte belongs to the leaf: a 4x4 tile grid is at
    // most 16 tile uploads; intermediates scatter into the pool without
    // a host→device transfer.
    let tile_bytes = (32 * 32 * 4) as u64;
    assert!(rep.stats.transfer_bytes <= 16 * tile_bytes);
    let pool = &c.residency_pools()[0];
    assert_eq!(pool.stats().uploaded_bytes, rep.stats.transfer_bytes);

    // Retirement: A² and A³ were freed when their last consumer ran —
    // only the leaf and the (still live) root remain resident.
    let root_tiles = 16; // 128/32 grid, all tiles accumulated
    assert!(
        pool.resident_tiles() <= 16 + root_tiles,
        "interior intermediates must be freed at retirement: {} tiles resident",
        pool.resident_tiles()
    );

    // Dropping the root and evicting releases the rest.
    let before = pool.resident_bytes();
    c.evict_value(rep.value);
    assert!(
        pool.resident_bytes() < before,
        "evicting the root must free its tiles"
    );
}

#[test]
fn warm_resubmit_transfers_nothing_and_skips_host_norms() {
    let c = coord(SpammConfig::default());
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 22);
    let g = power4_graph(1e-5);
    let plan = c.prepare_expr(&g, &[ExprSource::Host(&a)]).unwrap();
    let cold = c.execute_expr(&plan).unwrap();
    assert!(cold.stats.transfer_bytes > 0, "cold run uploads the leaf");

    let warm = c.execute_expr(&plan).unwrap();
    // Leaf tiles are pool hits, intermediates are produced on device:
    // a warm re-submit moves zero bytes host→device.
    assert_eq!(warm.stats.transfer_bytes, 0, "warm expr re-submit uploaded bytes");
    assert!(warm.stats.residency_hits > 0);
    // Schedules for the τ>0 downstream nodes were rebuilt from
    // device-refreshed norms on the cold run and cached under the derived
    // fingerprints — the warm run hits.
    assert!(warm.stats.schedule_cache_hits > 0);
    assert_eq!(
        warm.stats.norm_cache_misses, 0,
        "warm run must not host-recompute any normmap"
    );
    assert!(warm.stats.norms_refreshed > 0, "exact norms came from the device");
    // And the results agree bitwise.
    assert_eq!(cold.to_matrix().data(), warm.to_matrix().data());
}

#[test]
fn tau_zero_schedules_come_from_propagated_bounds() {
    let c = coord(SpammConfig::default());
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 23);
    let g = power4_graph(0.0);
    let plan = c.prepare_expr(&g, &[ExprSource::Host(&a)]).unwrap();
    let rep = c.execute_expr(&plan).unwrap();
    // At τ = 0 pruning cannot differ, so every node runs off the
    // prepare-time bound schedule: no exact refresh needed at all.
    assert_eq!(rep.stats.norms_propagated, 3);
    let loop_ref = spamm_power_loop(&coord(SpammConfig::default()), &a, 4, 0.0).unwrap();
    assert_eq!(rep.to_matrix().data(), loop_ref.value.data());
}

#[test]
fn axpby_scale_add_diag_match_host_combines() {
    let c = coord(SpammConfig::default());
    let x = Matrix::decay_exponential(96, 1.0, 0.5, 24);
    let y = Matrix::decay_exponential(96, 1.0, 0.5, 25);

    // 3·(X·Y) − 2·X, then scaled and diagonally shifted.
    let mut g = ExprGraph::new();
    let xi = g.operand();
    let yi = g.operand();
    let prod = g.spamm(xi, yi, Approx::Tau(0.0));
    let comb = g.axpby(3.0, prod, -2.0, xi);
    let scaled = g.scale(0.5, comb);
    let shifted = g.add_diag(1.25, scaled);
    g.output(shifted);
    let plan = c
        .prepare_expr(&g, &[ExprSource::Host(&x), ExprSource::Host(&y)])
        .unwrap();
    let rep = c.execute_expr(&plan).unwrap();

    // Host reference with the same elementwise expressions.
    let pr = coord(SpammConfig::default()).multiply(&x, &y, 0.0).unwrap().c;
    let mut want = Matrix::zeros(96, 96);
    for i in 0..96 {
        for j in 0..96 {
            let v = 3.0 * pr[(i, j)] + (-2.0) * x[(i, j)];
            let mut v = 0.5 * v;
            if i == j {
                v += 1.25;
            }
            want[(i, j)] = v;
        }
    }
    assert_eq!(rep.to_matrix().data(), want.data(), "device combine chain diverged");
}

#[test]
fn diff_fnorm_matches_error_fnorm_bitwise() {
    let c = coord(SpammConfig::default());
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 26);
    let mut g = ExprGraph::new();
    let ai = g.operand();
    let sq = g.spamm(ai, ai, Approx::Tau(0.0));
    let d = g.diff_fnorm(sq, ai);
    g.output(sq);
    let plan = c.prepare_expr(&g, &[ExprSource::Host(&a)]).unwrap();
    let rep = c.execute_expr(&plan).unwrap();
    let want = rep.to_matrix().error_fnorm(&a).unwrap();
    assert_eq!(
        rep.scalar(d).unwrap().to_bits(),
        want.to_bits(),
        "device-side ‖A²−A‖_F must equal the host computation bitwise"
    );
}

#[test]
fn chaining_via_resident_values_skips_all_leaf_rework() {
    // Two executions chained through ExprSource::Resident: the second
    // graph's input is the first's device-resident result.
    let c = coord(SpammConfig::default());
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 27);
    let mut g = ExprGraph::new();
    let ai = g.operand();
    let sq = g.spamm(ai, ai, Approx::Tau(1e-6));
    g.output(sq);
    let plan = c.prepare_expr(&g, &[ExprSource::Host(&a)]).unwrap();
    let first = c.execute_expr(&plan).unwrap();

    let norm_misses_before = c.caches().norms.misses();
    let plan2 = c
        .prepare_expr(&g, &[ExprSource::Resident(&first.value)])
        .unwrap();
    let second = c.execute_expr(&plan2).unwrap();
    // The chained prepare+execute never fingerprinted, padded, or normed
    // the intermediate on host.
    assert_eq!(
        c.caches().norms.misses(),
        norm_misses_before,
        "chaining must not host-recompute the resident input's normmap"
    );
    assert_eq!(second.stats.transfer_bytes, 0, "chained input is already resident");
    // (A²)² — reference via two loop multiplies (these may miss the norm
    // cache; they run after the counter assertion above).
    let ref_sq = c.multiply(&a, &a, 1e-6).unwrap().c;
    let want = c.multiply(&ref_sq, &ref_sq, 1e-6).unwrap().c;
    assert_eq!(second.to_matrix().data(), want.data());
}

#[test]
fn expr_runs_without_residency_pools() {
    // --no-residency: intermediates live purely as held handles; results
    // still match the loop path bitwise.
    let mut cfg = SpammConfig::default();
    cfg.residency_enabled = false;
    let c1 = coord(cfg.clone());
    let c2 = coord(cfg);
    let a = Matrix::decay_exponential(96, 1.0, 0.5, 28);
    let expr = spamm_power(&c1, &a, 3, 1e-5).unwrap();
    let looped = spamm_power_loop(&c2, &a, 3, 1e-5).unwrap();
    assert_eq!(expr.value.data(), looped.value.data());
}

#[test]
fn session_expr_tickets_round_trip() {
    let s = SpammSession::new(&bundle(), SpammConfig::default()).unwrap();
    let a = Matrix::decay_exponential(128, 1.0, 0.5, 29);
    let aid = s.put(&a).unwrap();
    let g = power4_graph(1e-5);
    let plan = s.prepare_expr(&g, &[aid]).unwrap();
    let (tau, rows, cols) = s.expr_plan_info(plan).unwrap();
    assert_eq!(tau, Some(1e-5));
    assert_eq!((rows, cols), (128, 128));

    let t1 = s.submit_expr(plan).unwrap();
    let t2 = s.submit_expr(plan).unwrap();
    let cold = s.wait(t1).unwrap();
    let warm = s.wait(t2).unwrap();
    // A graph is one queue job carrying per-node stats.
    assert_eq!(cold.nodes.len(), 3, "three spamm nodes reported");
    assert!(cold.nodes.iter().all(|n| n.op == "spamm"));
    assert_eq!(warm.stats.transfer_bytes, 0, "warm graph re-submit uploads");
    // Matches the coordinator-level execution bitwise.
    let c = coord(SpammConfig::default());
    let reference = spamm_power(&c, &a, 4, 1e-5).unwrap();
    assert_eq!(cold.c.data(), reference.value.data());
    assert_eq!(warm.c.data(), reference.value.data());

    // Release: plan refs drop, operand unpins, store releases cleanly.
    s.release_expr_plan(plan).unwrap();
    assert!(s.release_expr_plan(plan).is_err(), "double release");
    s.release(aid).unwrap();
}

#[test]
fn session_expr_plan_pins_store_operands() {
    let n = 64usize;
    let bytes = n * n * 4;
    let mut cfg = SpammConfig::default();
    cfg.store_budget = bytes; // room for one operand
    let s = SpammSession::new(&bundle(), cfg).unwrap();
    let a = s.put(&Matrix::decay_exponential(n, 1.0, 0.5, 30)).unwrap();
    let mut g = ExprGraph::new();
    let ai = g.operand();
    let sq = g.spamm(ai, ai, Approx::Tau(0.0));
    g.output(sq);
    let plan = s.prepare_expr(&g, &[a]).unwrap();
    s.release(a).unwrap();
    // Churn the store well past its budget...
    for seed in 40..44u64 {
        let x = s.put(&Matrix::decay_exponential(n, 1.0, 0.5, seed)).unwrap();
        s.release(x).unwrap();
    }
    // ...the expr-plan-pinned operand survives and the plan still runs.
    let done = s.wait(s.submit_expr(plan).unwrap()).unwrap();
    assert_eq!(done.c.rows(), n);
    s.release_expr_plan(plan).unwrap();
}
