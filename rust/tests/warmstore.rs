//! Robustness tests for the content-addressed warm-start store.  The
//! store must never be able to make a result wrong — only warm — so
//! every corruption mode here (truncation, bit flips, manifest/payload
//! disagreement, stale schema versions, racing writers) has the same
//! required outcome: the load falls back cold (`None`), nothing panics,
//! and the bad entry is evicted so the next save self-heals.  The last
//! test drives the end-to-end contract: a restarted `SpammSession` over
//! the same store directory answers its first request entirely from
//! disk, bitwise identical to the cold run.

mod common;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::{Approx, SpammSession};
use cuspamm::json::Value;
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::spamm::cache::{fingerprint, Fingerprint};
use cuspamm::spamm::normmap::{normmap_with_density, NormMap};
use cuspamm::store::WarmStore;

use common::bundle;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "cuspamm_warmstore_it_{}_{}",
        tag,
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A store with one persisted normmap, plus everything needed to verify
/// a restore of it.
fn seeded_store(dir: &Path) -> (WarmStore, Fingerprint, NormMap) {
    let store = WarmStore::open(dir).unwrap();
    let m = Matrix::randn(64, 64, 9);
    let p = PaddedMatrix::new(&m, 32);
    let nm = normmap_with_density(&p);
    let fp = fingerprint(&p);
    store.save_normmap(fp, &nm);
    (store, fp, nm)
}

fn payload_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    for ent in fs::read_dir(dir.join("objects")).unwrap() {
        let p = ent.unwrap().path();
        if p.extension().and_then(|e| e.to_str()) == Some("bin") {
            out.push(p);
        }
    }
    out
}

#[test]
fn truncated_payload_falls_back_cold_and_self_heals() {
    let dir = tmp_dir("trunc");
    let (store, fp, nm) = seeded_store(&dir);
    for p in payload_files(&dir) {
        let bytes = fs::read(&p).unwrap();
        fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
    }
    assert!(
        store.load_normmap(fp).is_none(),
        "a truncated payload must read as cold, never as data"
    );
    assert!(store.evictions() >= 1, "the bad entry must be evicted");
    // Evicted means gone: the manifest no longer names it.
    assert!(store.load_normmap(fp).is_none());
    // Self-heal: the next save repopulates and restores round-trip.
    store.save_normmap(fp, &nm);
    let back = store.load_normmap(fp).expect("store heals after a re-save");
    assert_eq!(back.norms.data(), nm.norms.data());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn bit_flipped_payload_fails_its_checksum() {
    let dir = tmp_dir("flip");
    let (store, fp, nm) = seeded_store(&dir);
    for p in payload_files(&dir) {
        let mut bytes = fs::read(&p).unwrap();
        // Flip one bit mid-payload: size and header stay plausible, so
        // only the checksum can catch it.
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        fs::write(&p, &bytes).unwrap();
    }
    assert!(store.load_normmap(fp).is_none(), "checksum must catch a bit flip");
    assert!(store.evictions() >= 1);
    store.save_normmap(fp, &nm);
    assert!(store.load_normmap(fp).is_some());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn manifest_payload_size_disagreement_is_cold() {
    let dir = tmp_dir("size");
    let (store, fp, nm) = seeded_store(&dir);
    // Grow every payload: content now disagrees with the manifest's
    // recorded byte size (the append also breaks the checksum, but the
    // size check fires first and must be enough on its own).
    for p in payload_files(&dir) {
        let mut bytes = fs::read(&p).unwrap();
        bytes.extend_from_slice(&[0u8; 4]);
        fs::write(&p, &bytes).unwrap();
    }
    assert!(store.load_normmap(fp).is_none());
    assert!(store.evictions() >= 1);
    store.save_normmap(fp, &nm);
    assert!(store.load_normmap(fp).is_some());
    let _ = fs::remove_dir_all(&dir);
}

/// Rewrite the manifest with every entry's schema version replaced.
fn rewrite_entry_versions(dir: &Path, version: f64) {
    let path = dir.join("manifest.json");
    let root = Value::parse(&fs::read_to_string(&path).unwrap()).unwrap();
    let mut entries = BTreeMap::new();
    for (k, v) in root.get("entries").unwrap().as_object().unwrap() {
        let mut obj = v.as_object().unwrap().clone();
        obj.insert("version".into(), Value::Number(version));
        entries.insert(k.clone(), Value::Object(obj));
    }
    let mut new_root = root.as_object().unwrap().clone();
    new_root.insert("entries".into(), Value::Object(entries));
    fs::write(&path, Value::Object(new_root).to_json()).unwrap();
}

#[test]
fn stale_entry_schema_version_is_cold() {
    let dir = tmp_dir("stale");
    let (store, fp, nm) = seeded_store(&dir);
    rewrite_entry_versions(&dir, 999.0);
    assert!(
        store.load_normmap(fp).is_none(),
        "an entry written under another schema version must be cold"
    );
    assert!(store.evictions() >= 1);
    store.save_normmap(fp, &nm);
    assert!(store.load_normmap(fp).is_some());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn stale_manifest_schema_version_is_cold_until_rewritten() {
    let dir = tmp_dir("staleman");
    let (store, fp, nm) = seeded_store(&dir);
    // Skew the *root* manifest version: the whole store reads as cold.
    let path = dir.join("manifest.json");
    let root = Value::parse(&fs::read_to_string(&path).unwrap()).unwrap();
    let mut new_root = root.as_object().unwrap().clone();
    new_root.insert("version".into(), Value::Number(999.0));
    fs::write(&path, Value::Object(new_root).to_json()).unwrap();
    assert!(store.load_normmap(fp).is_none());
    // The next save rewrites the manifest wholesale at the current
    // schema version, resurrecting the store.
    store.save_normmap(fp, &nm);
    assert!(store.load_normmap(fp).is_some());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_same_entry_writers_never_corrupt() {
    let dir = tmp_dir("race");
    let shared = Arc::new(WarmStore::open(&dir).unwrap());
    let m = Matrix::randn(64, 64, 13);
    let p = PaddedMatrix::new(&m, 32);
    let nm = Arc::new(normmap_with_density(&p));
    let fp = fingerprint(&p);
    // Same key, same content (the store is content-addressed, so racing
    // writers of one entry are always writing identical bytes): half the
    // threads share one handle, half open their own — the cross-process
    // shape.  Whoever wins each rename, the entry must load intact.
    let mut threads = Vec::new();
    for i in 0..8 {
        let dir = dir.clone();
        let shared = shared.clone();
        let nm = nm.clone();
        threads.push(std::thread::spawn(move || {
            let own;
            let store: &WarmStore = if i % 2 == 0 {
                &shared
            } else {
                own = WarmStore::open(&dir).unwrap();
                &own
            };
            for _ in 0..10 {
                store.save_normmap(fp, &nm);
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let restored = shared
        .load_normmap(fp)
        .expect("racing identical writers must leave a loadable entry");
    assert_eq!(restored.norms.data(), nm.norms.data());
    assert_eq!(restored.density.data(), nm.density.data());
    assert!(shared.verify(false).unwrap().bad.is_empty());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn restarted_session_is_warm_and_bitwise_identical() {
    let dir = tmp_dir("restart");
    let mut cfg = SpammConfig::default();
    cfg.store_dir = dir.to_string_lossy().into_owned();
    let b = bundle();
    let ma = Matrix::decay_algebraic(128, 0.1, 0.1, 21);
    let mb = Matrix::decay_algebraic(128, 0.1, 0.1, 22);
    // One full "process": fresh session, nothing shared in memory.
    let run = |cfg: &SpammConfig| {
        let s = SpammSession::new(&b, cfg.clone()).unwrap();
        let ida = s.put(&ma).unwrap();
        let idb = s.put(&mb).unwrap();
        let plan = s.prepare(ida, idb, Approx::ValidRatio(0.3)).unwrap();
        s.wait(s.submit(plan).unwrap()).unwrap()
    };

    let cold = run(&cfg);
    assert_eq!(cold.stats.tau_tuned, 1);
    assert_eq!(cold.stats.norm_cache_misses, 2);
    assert_eq!(cold.stats.schedule_cache_misses, 1);
    assert_eq!(
        cold.stats.store_normmap_hits + cold.stats.store_schedule_hits + cold.stats.store_tau_hits,
        0,
        "an empty store cannot produce hits"
    );

    let warm = run(&cfg);
    assert_eq!(
        (
            warm.stats.norm_cache_misses,
            warm.stats.schedule_cache_misses,
            warm.stats.tau_tuned
        ),
        (0, 0, 0),
        "the restarted session must not recompute anything"
    );
    assert_eq!(warm.stats.store_normmap_hits, 2);
    assert_eq!(warm.stats.store_schedule_hits, 1);
    assert_eq!(warm.stats.store_tau_hits, 1);
    assert_eq!(warm.tau.to_bits(), cold.tau.to_bits(), "restored τ drifted");
    assert_eq!(warm.c.data(), cold.c.data(), "warm result diverged");

    // Kill switch: with the store disabled the cold path runs end to end
    // and produces the identical bits.
    let mut off = cfg.clone();
    off.store_enabled = false;
    let dark = run(&off);
    assert_eq!(dark.stats.tau_tuned, 1);
    assert_eq!(
        dark.stats.store_normmap_hits + dark.stats.store_schedule_hits + dark.stats.store_tau_hits,
        0
    );
    assert_eq!(dark.c.data(), cold.c.data(), "no-store result diverged");
    let _ = fs::remove_dir_all(&dir);
}
