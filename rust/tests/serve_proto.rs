//! Wire-protocol conformance for the serving tier: golden frame layout,
//! round-trips for every frame kind, and an adversarial sweep — truncated
//! headers and payloads, wrong magic/version, unknown kind tags, hostile
//! length prefixes, non-UTF-8 and garbage payloads — against both the
//! codec and a live server.  Every corruption must surface as a *typed*
//! reply or error: never a panic, never a hang, never a poisoned server.

mod common;

use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;

use cuspamm::config::SpammConfig;
use cuspamm::error::Error;
use cuspamm::json::Value;
use cuspamm::matrix::Matrix;
use cuspamm::serve::proto::{
    self, decode_header, encode_frame, try_read_frame, FrameKind, HEADER_LEN, MAGIC, MAX_PAYLOAD,
    VERSION,
};
use cuspamm::serve::{PutOutcome, RemoteApprox, ServeClient, ServeServer, SubmitOutcome};

use common::bundle;

fn obj(fields: &[(&str, Value)]) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert((*k).to_string(), v.clone());
    }
    Value::Object(m)
}

#[test]
fn golden_frame_byte_layout() {
    // The exact on-wire bytes of a hello frame are a compatibility
    // contract: header fields little-endian, payload compact JSON.
    let payload = obj(&[("client", Value::String("a".into()))]);
    let bytes = encode_frame(FrameKind::Hello, &payload).unwrap();
    let body = br#"{"client":"a"}"#;
    assert_eq!(&bytes[0..4], &MAGIC.to_le_bytes());
    assert_eq!(&bytes[4..6], &VERSION.to_le_bytes());
    assert_eq!(bytes[6], 0x01, "hello tag");
    assert_eq!(bytes[7], 0, "reserved byte");
    assert_eq!(&bytes[8..12], &(body.len() as u32).to_le_bytes());
    assert_eq!(&bytes[HEADER_LEN..], body);
}

#[test]
fn every_frame_kind_roundtrips() {
    for &kind in FrameKind::all() {
        let payload = obj(&[
            ("tag", Value::Number(kind.to_tag() as f64)),
            ("data", Value::String(proto::encode_f32s(&[1.5, -0.0]))),
        ]);
        let bytes = encode_frame(kind, &payload).unwrap();
        let frame = try_read_frame(&mut &bytes[..]).unwrap().expect("one frame");
        assert_eq!(frame.kind, kind);
        assert_eq!(frame.payload, payload);
        // And the remainder of the stream is a clean boundary EOF.
        let mut rest: &[u8] = &[];
        assert!(try_read_frame(&mut rest).unwrap().is_none());
    }
}

#[test]
fn corrupt_headers_are_typed_errors() {
    let good = encode_frame(FrameKind::Stats, &obj(&[])).unwrap();
    let mut header = [0u8; HEADER_LEN];
    header.copy_from_slice(&good[..HEADER_LEN]);

    let mut wrong_magic = header;
    wrong_magic[0] ^= 0xff;
    assert!(matches!(decode_header(&wrong_magic), Err(Error::Protocol(_))));

    let mut wrong_version = header;
    wrong_version[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
    assert!(matches!(decode_header(&wrong_version), Err(Error::Protocol(_))));

    let mut unknown_kind = header;
    unknown_kind[6] = 0x7f;
    assert!(matches!(decode_header(&unknown_kind), Err(Error::Protocol(_))));

    let mut reserved = header;
    reserved[7] = 1;
    assert!(matches!(decode_header(&reserved), Err(Error::Protocol(_))));

    let mut oversized = header;
    oversized[8..12].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    assert!(matches!(decode_header(&oversized), Err(Error::Protocol(_))));
}

#[test]
fn corrupt_payloads_are_typed_errors() {
    // Valid header, payload bytes that are not UTF-8.
    let mut frame = encode_frame(FrameKind::Stats, &obj(&[])).unwrap();
    frame.truncate(HEADER_LEN);
    frame[8..12].copy_from_slice(&4u32.to_le_bytes());
    frame.extend_from_slice(&[0xff, 0xfe, 0xfd, 0xfc]);
    assert!(matches!(try_read_frame(&mut &frame[..]), Err(Error::Protocol(_))));

    // Valid header, payload that is not JSON.
    let mut garbage = encode_frame(FrameKind::Stats, &obj(&[])).unwrap();
    garbage.truncate(HEADER_LEN);
    garbage[8..12].copy_from_slice(&4u32.to_le_bytes());
    garbage.extend_from_slice(b"!!!!");
    assert!(matches!(try_read_frame(&mut &garbage[..]), Err(Error::Protocol(_))));

    // Every possible truncation point of a real frame.
    let bytes = encode_frame(
        FrameKind::Put,
        &obj(&[("data", Value::String(proto::encode_f32s(&[1.0, 2.0])))]),
    )
    .unwrap();
    for cut in 1..bytes.len() {
        let err = try_read_frame(&mut &bytes[..cut]).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "cut={cut}: {err}");
    }
}

fn start_server() -> ServeServer {
    let b = bundle();
    ServeServer::start(&b, SpammConfig::default(), "127.0.0.1:0").unwrap()
}

/// Write raw bytes, then read one reply frame off the same socket.
fn raw_exchange(addr: std::net::SocketAddr, bytes: &[u8]) -> (TcpStream, proto::Frame) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(bytes).unwrap();
    s.flush().unwrap();
    let reply = proto::read_frame(&mut s).unwrap();
    (s, reply)
}

#[test]
fn live_server_sheds_corrupt_frames_with_a_typed_reply_then_closes() {
    let server = start_server();
    let addr = server.local_addr();
    let good = encode_frame(FrameKind::Stats, &obj(&[])).unwrap();

    // Framing corruptions: the server answers with ErrorReply, then
    // closes (resync on a corrupt byte stream is impossible).
    let mut wrong_magic = good.clone();
    wrong_magic[0] ^= 0xff;
    let mut wrong_version = good.clone();
    wrong_version[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
    let mut unknown_kind = good.clone();
    unknown_kind[6] = 0x7f;
    let mut oversized = good.clone();
    oversized[8..12].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    let mut not_json = good.clone();
    not_json.truncate(HEADER_LEN);
    not_json[8..12].copy_from_slice(&4u32.to_le_bytes());
    not_json.extend_from_slice(b"!!!!");
    for (what, bytes) in [
        ("wrong magic", &wrong_magic),
        ("wrong version", &wrong_version),
        ("unknown kind", &unknown_kind),
        ("oversized length", &oversized),
        ("non-JSON payload", &not_json),
    ] {
        let (mut s, reply) = raw_exchange(addr, bytes);
        assert_eq!(reply.kind, FrameKind::ErrorReply, "{what}");
        // The server hangs up after losing framing — a clean EOF here,
        // not a hang.
        assert!(try_read_frame(&mut s).unwrap().is_none(), "{what}");
    }

    // Mid-frame truncation: declare a 64-byte payload, send 8, hang up
    // our write half.  The server must reply (typed) rather than wait
    // forever.
    let mut truncated = good.clone();
    truncated[8..12].copy_from_slice(&64u32.to_le_bytes());
    truncated.truncate(HEADER_LEN + 8);
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&truncated).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let reply = proto::read_frame(&mut s).unwrap();
    assert_eq!(reply.kind, FrameKind::ErrorReply);

    // None of that poisoned the server: a well-formed client still works.
    let mut c = ServeClient::connect(addr, "after-the-storm").unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.requests > 0);
    drop(c);
    server.shutdown();
}

#[test]
fn dispatch_errors_keep_the_connection_open() {
    let server = start_server();
    let addr = server.local_addr();

    // A request before hello is a dispatch error, not a framing error:
    // the reply is typed and the connection survives.
    let put = encode_frame(FrameKind::Put, &obj(&[("rows", Value::Number(1.0))])).unwrap();
    let (mut s, reply) = raw_exchange(addr, &put);
    assert_eq!(reply.kind, FrameKind::ErrorReply);
    let name = obj(&[("client", Value::String("raw".into()))]);
    let hello = encode_frame(FrameKind::Hello, &name).unwrap();
    s.write_all(&hello).unwrap();
    let reply = proto::read_frame(&mut s).unwrap();
    assert_eq!(reply.kind, FrameKind::HelloOk, "connection must survive a dispatch error");

    // A reply kind in request position is rejected without closing.
    let backwards = encode_frame(FrameKind::ResultOk, &obj(&[])).unwrap();
    s.write_all(&backwards).unwrap();
    let reply = proto::read_frame(&mut s).unwrap();
    assert_eq!(reply.kind, FrameKind::ErrorReply);

    // An empty tenant name is rejected.
    let empty = obj(&[("client", Value::String(String::new()))]);
    let anon = encode_frame(FrameKind::Hello, &empty).unwrap();
    s.write_all(&anon).unwrap();
    let reply = proto::read_frame(&mut s).unwrap();
    assert_eq!(reply.kind, FrameKind::ErrorReply);

    // Still alive: stats answers on the same socket.
    let stats = encode_frame(FrameKind::Stats, &obj(&[])).unwrap();
    s.write_all(&stats).unwrap();
    let reply = proto::read_frame(&mut s).unwrap();
    assert_eq!(reply.kind, FrameKind::StatsOk);
    drop(s);
    server.shutdown();
}

#[test]
fn unknown_handles_are_typed_session_errors() {
    use cuspamm::serve::{RemoteOperandId, RemotePlanId, RemoteTicket};
    let server = start_server();
    let mut c = ServeClient::connect(server.local_addr(), "handles").unwrap();
    let bad_op = RemoteOperandId(999);
    let bad_plan = RemotePlanId(999);
    let bad_ticket = RemoteTicket(999);
    for err in [
        c.prepare(bad_op, bad_op, RemoteApprox::Tau(0.0)).unwrap_err(),
        c.submit(bad_plan).map(|_| ()).unwrap_err(),
        c.wait(bad_ticket).map(|_| ()).unwrap_err(),
        c.release(bad_op).unwrap_err(),
        c.release_plan(bad_plan).unwrap_err(),
    ] {
        assert!(matches!(err, Error::Session(_)), "{err}");
    }
    // The connection survived all five rejections.
    let m = Matrix::decay_exponential(64, 1.0, 0.5, 3);
    let id = match c.put(&m).unwrap() {
        PutOutcome::Ok(id) => id,
        PutOutcome::QuotaExceeded(m) => panic!("unlimited budget shed a put: {m}"),
    };
    let plan = c.prepare(id, id, RemoteApprox::Tau(0.0)).unwrap();
    match c.submit(plan.id).unwrap() {
        SubmitOutcome::Ticket(t, cached) => {
            assert!(!cached);
            let done = c.wait(t).unwrap();
            assert!(done.executed);
            assert_eq!((done.c.rows(), done.c.cols()), (64, 64));
            // A ticket redeems exactly once.
            let again = c.wait(t).unwrap_err();
            assert!(matches!(again, Error::Session(_)), "{again}");
        }
        other => panic!("submit shed on an idle server: {other:?}"),
    }
    drop(c);
    server.shutdown();
}

#[test]
fn products_cross_the_wire_bitwise() {
    // The f32 hex codec end-to-end: a remote product must match the
    // in-process session bit for bit, including on re-decode of awkward
    // values (negative zero, subnormals survive encode_f32s round-trips).
    let data = vec![0.0f32, -0.0, f32::MIN_POSITIVE, 1.0e-39, -3.25e-12, 1e30];
    let dec = proto::decode_f32s(&proto::encode_f32s(&data)).unwrap();
    for (a, b) in data.iter().zip(&dec) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    let b = bundle();
    let server = ServeServer::start(&b, SpammConfig::default(), "127.0.0.1:0").unwrap();
    let mut c = ServeClient::connect(server.local_addr(), "bitwise").unwrap();
    let m = Matrix::decay_exponential(96, 1.0, 0.5, 5);
    let id = match c.put(&m).unwrap() {
        PutOutcome::Ok(id) => id,
        PutOutcome::QuotaExceeded(msg) => panic!("{msg}"),
    };
    let plan = c.prepare(id, id, RemoteApprox::Tau(1e-4)).unwrap();
    let remote = match c.submit(plan.id).unwrap() {
        SubmitOutcome::Ticket(t, _) => c.wait(t).unwrap(),
        other => panic!("{other:?}"),
    };
    use cuspamm::coordinator::{Approx, SpammSession};
    let s = SpammSession::new(&b, SpammConfig::default()).unwrap();
    let sid = s.put(&m).unwrap();
    let splan = s.prepare(sid, sid, Approx::Tau(1e-4)).unwrap();
    let direct = s.wait(s.submit(splan).unwrap()).unwrap();
    assert_eq!(remote.c.data(), direct.c.data(), "wire transport changed bits");
    drop(c);
    server.shutdown();
}
