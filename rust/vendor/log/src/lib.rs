//! Vendored subset of the `log` facade crate (the offline build has no
//! crates.io access).  Implements exactly the API surface cuspamm uses:
//! the five level macros, the [`Log`] trait, [`set_logger`] /
//! [`set_max_level`], and the level/filter types with their cross
//! comparisons.  Semantics match the real crate for that subset, so the
//! vendored crate can be replaced by the upstream one via a Cargo
//! `[patch]` without source changes.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logging verbosity level of a record, most severe first.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record about to be logged.
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging sink.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned by [`set_logger`] when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger has already been installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not public API; use the level macros.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_comparisons() {
        assert!(Level::Error <= LevelFilter::Warn);
        assert!(Level::Debug > LevelFilter::Warn);
        assert!(LevelFilter::Trace >= Level::Trace);
        assert!(Level::Warn <= LevelFilter::Warn);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn macros_do_not_panic_without_logger() {
        error!("e {}", 1);
        warn!("w");
        info!("i");
        debug!("d");
        trace!("t");
    }
}
