//! Offline PJRT simulator with the `xla-rs` API surface cuspamm uses.
//!
//! The real build of this system AOT-compiles JAX/Pallas kernels to HLO
//! text and executes them through PJRT.  This vendored stand-in keeps the
//! exact client API (`PjRtClient` → `compile` → `execute` → `Literal`) but
//! "compiles" a self-describing *hostsim* artifact format instead of HLO:
//!
//! ```text
//! hostsim v1
//! kind = tilegemm
//! batch = 64
//! lonum = 32
//! precision = f32
//! ```
//!
//! Each artifact kind is interpreted with the same numeric contract as the
//! corresponding Pallas kernel (f32 accumulation; bf16 operand rounding
//! with round-to-nearest-even for the MXU variants).  Genuine HLO text is
//! rejected at compile time with a clear error, mirroring where a real
//! PJRT stack would fail on a corrupt module.
//!
//! Like the real `xla-rs`, the client is intentionally `!Send`: one client
//! per device thread is the honest model of one context per GPU.

use std::borrow::Borrow;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::rc::Rc;

/// Error type mirroring `xla::Error` (message-only here).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types of literals (f32 is the only one this build moves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Array shape of a non-tuple literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host literal: an f32 array or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal(Repr);

#[derive(Clone, Debug)]
enum Repr {
    Array { dims: Vec<usize>, data: Vec<f32> },
    Tuple(Vec<Literal>),
}

/// Element types extractable from a literal via [`Literal::to_vec`].
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn collect_from(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn collect_from(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.0 {
            Repr::Array { data, .. } => Ok(data.clone()),
            Repr::Tuple(_) => Err(Error::new("to_vec on a tuple literal")),
        }
    }
}

impl Literal {
    /// Build an array literal from raw bytes (native endianness), the
    /// layout `literal_f32` produces.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let ElementType::F32 = ty;
        if data.len() % 4 != 0 {
            return Err(Error::new("untyped f32 data not a multiple of 4 bytes"));
        }
        let count: usize = dims.iter().product::<usize>().max(1);
        let values: Vec<f32> = data
            .chunks_exact(4)
            .map(|b| f32::from_ne_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        if values.len() != count {
            return Err(Error::new(format!(
                "shape {dims:?} needs {count} f32 values, got {}",
                values.len()
            )));
        }
        Ok(Literal(Repr::Array {
            dims: dims.to_vec(),
            data: values,
        }))
    }

    fn array(dims: Vec<usize>, data: Vec<f32>) -> Literal {
        debug_assert_eq!(dims.iter().product::<usize>().max(1), data.len().max(1));
        Literal(Repr::Array { dims, data })
    }

    fn tuple(parts: Vec<Literal>) -> Literal {
        Literal(Repr::Tuple(parts))
    }

    /// Shape of an array literal (tuples have no array shape).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.0 {
            Repr::Array { dims, .. } => Ok(ArrayShape {
                dims: dims.iter().map(|&d| d as i64).collect(),
            }),
            Repr::Tuple(_) => Err(Error::new("array_shape on a tuple literal")),
        }
    }

    /// Copy the elements out of an array literal.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::collect_from(self)
    }

    /// Split a tuple literal into its parts (consumes the contents).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.0 {
            Repr::Tuple(parts) => Ok(std::mem::take(parts)),
            Repr::Array { .. } => Err(Error::new("decompose_tuple on an array literal")),
        }
    }

    fn dims_and_data(&self) -> Result<(&[usize], &[f32])> {
        match &self.0 {
            Repr::Array { dims, data } => Ok((dims, data)),
            Repr::Tuple(_) => Err(Error::new("expected an array literal, got a tuple")),
        }
    }
}

/// Parsed module text (HLO in the real stack, hostsim here).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("{}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation ready for compilation.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            text: proto.text.clone(),
        }
    }
}

/// The per-device client.  `!Send` on purpose (`Rc` marker), matching the
/// real binding: one client per device thread.
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    /// A CPU-backed client (the only backend of the simulator).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _not_send: Rc::new(()) })
    }

    /// "Compile" a computation: parse the hostsim spec.  Non-hostsim text
    /// (e.g. real or corrupt HLO) fails here, like a PJRT compile would.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let spec = OpSpec::parse(&comp.text)?;
        Ok(PjRtLoadedExecutable {
            spec,
            _not_send: Rc::new(()),
        })
    }
}

/// A device buffer holding one execution output.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled (interpretable) executable.
pub struct PjRtLoadedExecutable {
    spec: OpSpec,
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    /// Execute on host literals.  Returns per-device, per-output buffers
    /// like the real API; the root output is always a tuple literal.
    pub fn execute<L: Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let inputs: Vec<&Literal> = args.iter().map(|l| l.borrow()).collect();
        let outputs = self.spec.run(&inputs)?;
        Ok(vec![vec![PjRtBuffer {
            literal: Literal::tuple(outputs),
        }]])
    }
}

// ---- hostsim interpreter ---------------------------------------------------

#[derive(Clone, Debug)]
enum OpSpec {
    /// C[m,n] = A[m,k] · B[k,n].
    Dense { m: usize, k: usize, n: usize, bf16: bool },
    /// Per-slot C[b] = A[b] · B[b] over `batch` lonum×lonum tiles.
    TileGemm { batch: usize, lonum: usize, bf16: bool },
    /// Per-slot C[b] = α·X[b] + β·Y[b] over `batch` lonum×lonum tiles —
    /// the tiled linear-combination kernel expression graphs use for
    /// McWeeny's 3P² − 2P³ combine without leaving the device.
    Axpby { batch: usize, lonum: usize },
    /// Tile Frobenius norms of an n×n matrix.
    GetNorm { n: usize, lonum: usize, bf16: bool },
    /// τ search over normmap products for a target valid ratio.
    Tune { bdim: usize },
    /// Fused SpAMM: normmaps + masked tile multiply in one call.
    SpammFused { n: usize, lonum: usize, bf16: bool },
    /// Sparse tile product over COO-packed operands: C[l,l] += A·B where
    /// A is l×(run·l) and B is (run·l)×l, both given as padded
    /// (values, linear-indices) arrays of capacity `cap` plus a 2-entry
    /// meta array holding the live entry counts.  `run > 1` is the packed
    /// path: a fused run of `run` sparse tile-pair products dispatched as
    /// one wider contraction.
    Sptile { lonum: usize, run: usize, cap: usize },
}

fn parse_usize(kv: &BTreeMap<String, String>, key: &str) -> Result<usize> {
    kv.get(key)
        .ok_or_else(|| Error::new(format!("hostsim spec missing '{key}'")))?
        .parse()
        .map_err(|_| Error::new(format!("hostsim spec: bad integer for '{key}'")))
}

fn parse_bf16(kv: &BTreeMap<String, String>) -> bool {
    matches!(kv.get("precision").map(String::as_str), Some("bf16"))
}

impl OpSpec {
    fn parse(text: &str) -> Result<OpSpec> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("").trim();
        if !header.starts_with("hostsim") {
            return Err(Error::new(
                "not a hostsim artifact (this offline simulator cannot compile raw HLO)",
            ));
        }
        let mut kv = BTreeMap::new();
        for line in lines {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::new(format!("hostsim spec: bad line '{line}'")))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        match kv.get("kind").map(String::as_str) {
            Some("dense") => Ok(OpSpec::Dense {
                m: parse_usize(&kv, "m")?,
                k: parse_usize(&kv, "k")?,
                n: parse_usize(&kv, "n")?,
                bf16: parse_bf16(&kv),
            }),
            Some("tilegemm") => Ok(OpSpec::TileGemm {
                batch: parse_usize(&kv, "batch")?,
                lonum: parse_usize(&kv, "lonum")?,
                bf16: parse_bf16(&kv),
            }),
            Some("axpby") => Ok(OpSpec::Axpby {
                batch: parse_usize(&kv, "batch")?,
                lonum: parse_usize(&kv, "lonum")?,
            }),
            Some("getnorm") => Ok(OpSpec::GetNorm {
                n: parse_usize(&kv, "n")?,
                lonum: parse_usize(&kv, "lonum")?,
                bf16: matches!(kv.get("mxu").map(String::as_str), Some("true"))
                    || parse_bf16(&kv),
            }),
            Some("tune") => Ok(OpSpec::Tune {
                bdim: parse_usize(&kv, "bdim")?,
            }),
            Some("spamm_fused") => Ok(OpSpec::SpammFused {
                n: parse_usize(&kv, "n")?,
                lonum: parse_usize(&kv, "lonum")?,
                bf16: parse_bf16(&kv),
            }),
            Some("sptile") => Ok(OpSpec::Sptile {
                lonum: parse_usize(&kv, "lonum")?,
                run: parse_usize(&kv, "run")?,
                cap: parse_usize(&kv, "cap")?,
            }),
            Some(other) => Err(Error::new(format!("hostsim spec: unknown kind '{other}'"))),
            None => Err(Error::new("hostsim spec missing 'kind'")),
        }
    }

    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        match *self {
            OpSpec::Dense { m, k, n, bf16 } => {
                let a = expect_input(inputs, 0, &[m, k])?;
                let b = expect_input(inputs, 1, &[k, n])?;
                expect_arity(inputs, 2)?;
                let (a, b) = maybe_quantize2(a, b, bf16);
                Ok(vec![Literal::array(vec![m, n], matmul(&a, &b, m, k, n))])
            }
            OpSpec::TileGemm { batch, lonum, bf16 } => {
                let a = expect_input(inputs, 0, &[batch, lonum, lonum])?;
                let b = expect_input(inputs, 1, &[batch, lonum, lonum])?;
                expect_arity(inputs, 2)?;
                let (a, b) = maybe_quantize2(a, b, bf16);
                let l2 = lonum * lonum;
                let mut out = vec![0.0f32; batch * l2];
                for s in 0..batch {
                    tile_matmul(
                        &a[s * l2..(s + 1) * l2],
                        &b[s * l2..(s + 1) * l2],
                        &mut out[s * l2..(s + 1) * l2],
                        lonum,
                    );
                }
                Ok(vec![Literal::array(vec![batch, lonum, lonum], out)])
            }
            OpSpec::Axpby { batch, lonum } => {
                let x = expect_input(inputs, 0, &[batch, lonum, lonum])?;
                let y = expect_input(inputs, 1, &[batch, lonum, lonum])?;
                let alpha = expect_scalar(inputs, 2)?;
                let beta = expect_scalar(inputs, 3)?;
                expect_arity(inputs, 4)?;
                let out: Vec<f32> = x
                    .iter()
                    .zip(y)
                    .map(|(&xv, &yv)| alpha * xv + beta * yv)
                    .collect();
                Ok(vec![Literal::array(vec![batch, lonum, lonum], out)])
            }
            OpSpec::GetNorm { n, lonum, bf16 } => {
                let m = expect_input(inputs, 0, &[n, n])?;
                expect_arity(inputs, 1)?;
                let m = maybe_quantize(m, bf16);
                let bdim = n / lonum;
                Ok(vec![Literal::array(
                    vec![bdim, bdim],
                    normmap(&m, n, lonum),
                )])
            }
            OpSpec::Tune { bdim } => {
                let na = expect_input(inputs, 0, &[bdim, bdim])?;
                let nb = expect_input(inputs, 1, &[bdim, bdim])?;
                let target = expect_scalar(inputs, 2)?;
                expect_arity(inputs, 3)?;
                let (tau, ratio) = tune(na, nb, bdim, target);
                Ok(vec![
                    Literal::array(vec![], vec![tau]),
                    Literal::array(vec![], vec![ratio]),
                ])
            }
            OpSpec::SpammFused { n, lonum, bf16 } => {
                let a = expect_input(inputs, 0, &[n, n])?;
                let b = expect_input(inputs, 1, &[n, n])?;
                let tau = expect_scalar(inputs, 2)?;
                expect_arity(inputs, 3)?;
                let (a, b) = maybe_quantize2(a, b, bf16);
                Ok(vec![Literal::array(
                    vec![n, n],
                    spamm_fused(&a, &b, tau, n, lonum),
                )])
            }
            OpSpec::Sptile { lonum, run, cap } => {
                let a_vals = expect_input(inputs, 0, &[cap])?;
                let a_idx = expect_input(inputs, 1, &[cap])?;
                let b_vals = expect_input(inputs, 2, &[cap])?;
                let b_idx = expect_input(inputs, 3, &[cap])?;
                let meta = expect_input(inputs, 4, &[2])?;
                expect_arity(inputs, 5)?;
                let (a_nnz, b_nnz) = (meta[0] as usize, meta[1] as usize);
                if a_nnz > cap || b_nnz > cap {
                    return Err(Error::new(format!(
                        "sptile: nnz ({a_nnz}, {b_nnz}) exceeds capacity {cap}"
                    )));
                }
                Ok(vec![Literal::array(
                    vec![lonum, lonum],
                    sptile(
                        &a_vals[..a_nnz],
                        &a_idx[..a_nnz],
                        &b_vals[..b_nnz],
                        &b_idx[..b_nnz],
                        lonum,
                        run * lonum,
                    )?,
                )])
            }
        }
    }
}

fn expect_arity(inputs: &[&Literal], want: usize) -> Result<()> {
    if inputs.len() != want {
        return Err(Error::new(format!(
            "expected {want} inputs, got {}",
            inputs.len()
        )));
    }
    Ok(())
}

fn expect_input<'a>(inputs: &[&'a Literal], idx: usize, dims: &[usize]) -> Result<&'a [f32]> {
    let lit = inputs
        .get(idx)
        .ok_or_else(|| Error::new(format!("missing input {idx}")))?;
    let (got_dims, data) = lit.dims_and_data()?;
    if got_dims != dims {
        return Err(Error::new(format!(
            "input {idx}: shape {got_dims:?} does not match compiled shape {dims:?}"
        )));
    }
    Ok(data)
}

fn expect_scalar(inputs: &[&Literal], idx: usize) -> Result<f32> {
    let lit = inputs
        .get(idx)
        .ok_or_else(|| Error::new(format!("missing input {idx}")))?;
    let (dims, data) = lit.dims_and_data()?;
    if !dims.is_empty() || data.len() != 1 {
        return Err(Error::new(format!(
            "input {idx}: expected a scalar, got shape {dims:?}"
        )));
    }
    Ok(data[0])
}

/// bf16 round-to-nearest-even (XLA convert semantics).
fn bf16_quantize(x: f32) -> f32 {
    let bits = x.to_bits();
    if x.is_nan() {
        return f32::from_bits((bits >> 16 << 16) | 0x0040_0000);
    }
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
    f32::from_bits(rounded >> 16 << 16)
}

fn maybe_quantize(data: &[f32], bf16: bool) -> Vec<f32> {
    if bf16 {
        data.iter().map(|&x| bf16_quantize(x)).collect()
    } else {
        data.to_vec()
    }
}

fn maybe_quantize2(a: &[f32], b: &[f32], bf16: bool) -> (Vec<f32>, Vec<f32>) {
    (maybe_quantize(a, bf16), maybe_quantize(b, bf16))
}

fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let crow = &mut out[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    out
}

fn tile_matmul(a: &[f32], b: &[f32], c: &mut [f32], l: usize) {
    c.fill(0.0);
    for i in 0..l {
        for k in 0..l {
            let av = a[i * l + k];
            if av == 0.0 {
                continue;
            }
            let brow = &b[k * l..(k + 1) * l];
            let crow = &mut c[i * l..(i + 1) * l];
            for j in 0..l {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Tile Frobenius norms, f64 accumulation → f32 result (kernel contract).
fn normmap(m: &[f32], n: usize, lonum: usize) -> Vec<f32> {
    let bdim = n / lonum;
    let mut out = vec![0.0f32; bdim * bdim];
    for ti in 0..bdim {
        for tj in 0..bdim {
            let mut acc = 0.0f64;
            for r in 0..lonum {
                let row = &m[(ti * lonum + r) * n + tj * lonum..][..lonum];
                for &x in row {
                    acc += (x as f64) * (x as f64);
                }
            }
            out[ti * bdim + tj] = acc.sqrt() as f32;
        }
    }
    out
}

/// Quantile-based τ search: the (1 − target)-quantile of the norm-product
/// distribution hits the target valid ratio exactly up to count
/// quantization — same contract as the on-device tuning graph.
fn tune(na: &[f32], nb: &[f32], bdim: usize, target: f32) -> (f32, f32) {
    let mut products = Vec::with_capacity(bdim * bdim * bdim);
    for i in 0..bdim {
        for k in 0..bdim {
            let av = na[i * bdim + k];
            for j in 0..bdim {
                products.push(av * nb[k * bdim + j]);
            }
        }
    }
    if products.is_empty() {
        return (0.0, 1.0);
    }
    products.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    let total = products.len();
    let keep = ((target as f64) * total as f64).round() as usize;
    let tau = if keep == 0 {
        products[0] * 2.0 + 1.0
    } else {
        products[keep.min(total) - 1]
    };
    let count = products.iter().filter(|&&p| p >= tau).count();
    (tau, count as f32 / total as f32)
}

/// Fused SpAMM with the flat-host contract: mask on f32 norm products,
/// per-tile f32 matmuls accumulated in ascending k.
fn spamm_fused(a: &[f32], b: &[f32], tau: f32, n: usize, lonum: usize) -> Vec<f32> {
    let bdim = n / lonum;
    let na = normmap(a, n, lonum);
    let nb = normmap(b, n, lonum);
    let l2 = lonum * lonum;
    let mut ta = vec![0.0f32; l2];
    let mut tb = vec![0.0f32; l2];
    let mut tc = vec![0.0f32; l2];
    let mut out = vec![0.0f32; n * n];
    for i in 0..bdim {
        for j in 0..bdim {
            for k in 0..bdim {
                if na[i * bdim + k] * nb[k * bdim + j] < tau {
                    continue;
                }
                copy_tile(a, n, i, k, lonum, &mut ta);
                copy_tile(b, n, k, j, lonum, &mut tb);
                tile_matmul(&ta, &tb, &mut tc, lonum);
                add_tile(&mut out, n, i, j, lonum, &tc);
            }
        }
    }
    out
}

/// Sparse tile contraction: C[l×l] = A[l×kw]·B[kw×l] over COO entry lists
/// (values + row-major linear indices).  Gustavson row-wise order: B is
/// bucketed by contraction row, then A entries stream in stored order —
/// the same accumulation order per output element as a CSR SpGEMM over
/// the same sorted entries, which is the host-fallback contract.
fn sptile(
    a_vals: &[f32],
    a_idx: &[f32],
    b_vals: &[f32],
    b_idx: &[f32],
    l: usize,
    kw: usize,
) -> Result<Vec<f32>> {
    let mut b_rows: Vec<Vec<(usize, f32)>> = vec![Vec::new(); kw];
    for (&idx, &v) in b_idx.iter().zip(b_vals) {
        let idx = idx as usize;
        let (r, c) = (idx / l, idx % l);
        if r >= kw {
            return Err(Error::new(format!(
                "sptile: B index {idx} out of range {kw}x{l}"
            )));
        }
        b_rows[r].push((c, v));
    }
    let mut out = vec![0.0f32; l * l];
    for (&idx, &av) in a_idx.iter().zip(a_vals) {
        let idx = idx as usize;
        let (r, k) = (idx / kw, idx % kw);
        if r >= l {
            return Err(Error::new(format!(
                "sptile: A index {idx} out of range {l}x{kw}"
            )));
        }
        let crow = &mut out[r * l..(r + 1) * l];
        for &(c, bv) in &b_rows[k] {
            crow[c] += av * bv;
        }
    }
    Ok(out)
}

fn copy_tile(m: &[f32], n: usize, ti: usize, tj: usize, l: usize, dst: &mut [f32]) {
    for r in 0..l {
        let src = &m[(ti * l + r) * n + tj * l..][..l];
        dst[r * l..(r + 1) * l].copy_from_slice(src);
    }
}

fn add_tile(m: &mut [f32], n: usize, ti: usize, tj: usize, l: usize, src: &[f32]) {
    for r in 0..l {
        let dst = &mut m[(ti * l + r) * n + tj * l..][..l];
        for (d, s) in dst.iter_mut().zip(&src[r * l..(r + 1) * l]) {
            *d += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit(dims: &[usize], data: &[f32]) -> Literal {
        Literal::array(dims.to_vec(), data.to_vec())
    }

    fn run(text: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto {
            text: text.to_string(),
        };
        let exe = client.compile(&XlaComputation::from_proto(&proto))?;
        let bufs = exe.execute::<Literal>(inputs)?;
        let mut root = bufs[0][0].to_literal_sync()?;
        root.decompose_tuple()
    }

    #[test]
    fn rejects_raw_hlo() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto {
            text: "HloModule bad\nthis is not hlo".to_string(),
        };
        assert!(client.compile(&XlaComputation::from_proto(&proto)).is_err());
    }

    #[test]
    fn dense_identity() {
        let spec = "hostsim v1\nkind = dense\nm = 2\nk = 2\nn = 2\nprecision = f32";
        let a = lit(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let eye = lit(&[2, 2], &[1.0, 0.0, 0.0, 1.0]);
        let out = run(spec, &[a, eye]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn dense_rejects_wrong_shape() {
        let spec = "hostsim v1\nkind = dense\nm = 2\nk = 2\nn = 2\nprecision = f32";
        let a = lit(&[3, 3], &[0.0; 9]);
        assert!(run(spec, &[a.clone(), a]).is_err());
    }

    #[test]
    fn tilegemm_pads_zero() {
        let spec = "hostsim v1\nkind = tilegemm\nbatch = 2\nlonum = 2\nprecision = f32";
        let a = lit(&[2, 2, 2], &[1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        let b = lit(&[2, 2, 2], &[5.0, 6.0, 7.0, 8.0, 1.0, 1.0, 1.0, 1.0]);
        let out = run(spec, &[a, b]).unwrap();
        let v = out[0].to_vec::<f32>().unwrap();
        assert_eq!(&v[..4], &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(&v[4..], &[0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn axpby_combines_tiles() {
        let spec = "hostsim v1\nkind = axpby\nbatch = 2\nlonum = 2";
        let x = lit(&[2, 2, 2], &[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        let y = lit(&[2, 2, 2], &[1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
        let out = run(
            spec,
            &[x, y, lit(&[], &[3.0]), lit(&[], &[-2.0])],
        )
        .unwrap();
        let v = out[0].to_vec::<f32>().unwrap();
        assert_eq!(&v[..4], &[1.0, 4.0, 7.0, 10.0]);
        assert_eq!(&v[4..], &[-4.0, -4.0, -4.0, -4.0]);
    }

    #[test]
    fn getnorm_single_tile() {
        let spec = "hostsim v1\nkind = getnorm\nn = 2\nlonum = 2";
        let a = lit(&[2, 2], &[3.0, 0.0, 0.0, 4.0]);
        let out = run(spec, &[a]).unwrap();
        assert_eq!(out[0].to_vec::<f32>().unwrap(), vec![5.0]);
    }

    #[test]
    fn tune_hits_target() {
        let spec = "hostsim v1\nkind = tune\nbdim = 4";
        let na: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let nb: Vec<f32> = (1..=16).map(|i| (17 - i) as f32).collect();
        let out = run(
            spec,
            &[lit(&[4, 4], &na), lit(&[4, 4], &nb), lit(&[], &[0.25])],
        )
        .unwrap();
        let ratio = out[1].to_vec::<f32>().unwrap()[0];
        assert!((ratio - 0.25).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn sptile_matches_dense_contraction() {
        // run=2, lonum=2: A is 2x4 with entries (0,0)=2 and (1,3)=3;
        // B is 4x2 with entries (0,1)=5 and (3,0)=7.
        // C = A·B → C[0,1] = 2·5 = 10, C[1,0] = 3·7 = 21.
        let spec = "hostsim v1\nkind = sptile\nlonum = 2\nrun = 2\ncap = 4";
        let a_vals = lit(&[4], &[2.0, 3.0, 0.0, 0.0]);
        let a_idx = lit(&[4], &[0.0, 7.0, 0.0, 0.0]); // linear over 2x4
        let b_vals = lit(&[4], &[5.0, 7.0, 0.0, 0.0]);
        let b_idx = lit(&[4], &[1.0, 6.0, 0.0, 0.0]); // linear over 4x2
        let meta = lit(&[2], &[2.0, 2.0]);
        let out = run(spec, &[a_vals, a_idx, b_vals, b_idx, meta]).unwrap();
        assert_eq!(
            out[0].to_vec::<f32>().unwrap(),
            vec![0.0, 10.0, 21.0, 0.0]
        );
    }

    #[test]
    fn sptile_rejects_overflow_and_bad_indices() {
        let spec = "hostsim v1\nkind = sptile\nlonum = 2\nrun = 1\ncap = 2";
        let zeros = lit(&[2], &[0.0, 0.0]);
        // nnz beyond capacity.
        let meta = lit(&[2], &[3.0, 0.0]);
        assert!(run(
            spec,
            &[zeros.clone(), zeros.clone(), zeros.clone(), zeros.clone(), meta]
        )
        .is_err());
        // Out-of-range A index.
        let bad_idx = lit(&[2], &[99.0, 0.0]);
        let meta = lit(&[2], &[1.0, 0.0]);
        assert!(run(
            spec,
            &[zeros.clone(), bad_idx, zeros.clone(), zeros, meta]
        )
        .is_err());
    }

    #[test]
    fn bf16_dense_quantizes() {
        let spec = "hostsim v1\nkind = dense\nm = 1\nk = 1\nn = 1\nprecision = bf16";
        let a = lit(&[1, 1], &[1.001]);
        let b = lit(&[1, 1], &[1.0]);
        let out = run(spec, &[a, b]).unwrap();
        let v = out[0].to_vec::<f32>().unwrap()[0];
        assert_ne!(v, 1.001, "bf16 must quantize");
        assert!((v - 1.0).abs() < 0.01);
    }
}
