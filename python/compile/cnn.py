# Build-time CNN for the VGG13/MNIST case study (cuSpAMM §4.3.2), scaled to
# this testbed (DESIGN.md §2): a 3-conv CNN on a synthetic 16×16 digits
# dataset.  Every conv is expressed as an im2col GEMM, exactly the transform
# the paper applies to VGG13, so the Rust inference engine can substitute any
# conv GEMM with the SpAMM pipeline and sweep τ / valid-ratio against
# end-task accuracy (Table 5).
#
# Runs ONCE during `make artifacts`; exports weights + the frozen test set
# via tensorio so the Rust request path never touches Python.

import numpy as np
import jax
import jax.numpy as jnp

# Architecture (input 1×16×16) — channel widths sized so the im2col GEMMs
# match the paper's conv21/conv31 *tile granularity* (the weights matrix
# must span many LoNum=32 tiles in K, or SpAMM's tile skipping is
# catastrophically coarse — paper conv21 is 128×576, ours is 64×576):
#   conv1: 1→64,  3×3, pad 1 → relu → maxpool2   (16×16 → 8×8)
#   conv2: 64→64, 3×3, pad 1 → relu → maxpool2   (8×8 → 4×4)   ["conv21" analog: 64×576 GEMM]
#   conv3: 64→128, 3×3, pad 1 → relu             (4×4)         ["conv31" analog: 128×576 GEMM]
#   fc:    2048 → 10
CONV_SPECS = [
    ("conv1", 1, 64),
    ("conv2", 64, 64),
    ("conv3", 64, 128),
]
IMG = 16
NUM_CLASSES = 10
FC_IN = 128 * 4 * 4


def make_dataset(seed=7, n_train=2000, n_test=500):
    """Synthetic 'digits': smooth per-class templates + shift + noise."""
    rng = np.random.default_rng(seed)
    # Smooth random template per class (low-frequency cosine mixture).
    xs = np.arange(IMG)
    grid_y, grid_x = np.meshgrid(xs, xs, indexing="ij")
    templates = []
    for _ in range(NUM_CLASSES):
        t = np.zeros((IMG, IMG))
        for _ in range(4):
            fy, fx = rng.uniform(0.2, 1.2, 2)
            py, px = rng.uniform(0, 2 * np.pi, 2)
            t += rng.uniform(0.5, 1.5) * np.cos(fy * grid_y + py) * np.cos(fx * grid_x + px)
        t = (t - t.mean()) / (t.std() + 1e-6)
        templates.append(t)
    templates = np.stack(templates)

    def sample(n):
        labels = rng.integers(0, NUM_CLASSES, n)
        imgs = templates[labels].copy()
        # random circular shift ±2 px + noise
        for i in range(n):
            sy, sx = rng.integers(-2, 3, 2)
            imgs[i] = np.roll(np.roll(imgs[i], sy, axis=0), sx, axis=1)
        imgs += rng.normal(0, 0.35, imgs.shape)
        return imgs.astype(np.float32)[:, None], labels.astype(np.int32)

    return sample(n_train), sample(n_test)


def im2col(x, ksize=3, pad=1):
    """NCHW → (C·k·k, N·H·W) patch matrix — the paper's im2col transform."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    cols = []
    for dy in range(ksize):
        for dx in range(ksize):
            cols.append(xp[:, :, dy:dy + h, dx:dx + w])
    # (k·k, N, C, H, W) → (C, k·k, N, H, W) → (C·k·k, N·H·W)
    patches = jnp.stack(cols)  # (k², N, C, H, W)
    patches = patches.transpose(2, 0, 1, 3, 4).reshape(c * ksize * ksize, n * h * w)
    return patches


def conv_gemm(params_w, params_b, x):
    """Convolution as weight-matrix @ im2col-patches (+bias), NCHW."""
    n, c, h, w = x.shape
    cols = im2col(x)
    out = params_w @ cols + params_b[:, None]  # (C_out, N·H·W)
    c_out = params_w.shape[0]
    return out.reshape(c_out, n, h, w).transpose(1, 0, 2, 3)


def maxpool2(x):
    n, c, h, w = x.shape
    return x.reshape(n, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def forward(params, x):
    x = jax.nn.relu(conv_gemm(params["conv1_w"], params["conv1_b"], x))
    x = maxpool2(x)
    x = jax.nn.relu(conv_gemm(params["conv2_w"], params["conv2_b"], x))
    x = maxpool2(x)
    x = jax.nn.relu(conv_gemm(params["conv3_w"], params["conv3_b"], x))
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc_w"] + params["fc_b"]


def init_params(seed=0):
    rng = np.random.default_rng(seed)
    params = {}
    for name, cin, cout in CONV_SPECS:
        fan_in = cin * 9
        params[f"{name}_w"] = jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / fan_in), (cout, fan_in)), jnp.float32
        )
        params[f"{name}_b"] = jnp.zeros((cout,), jnp.float32)
    params["fc_w"] = jnp.asarray(
        rng.normal(0, np.sqrt(2.0 / FC_IN), (FC_IN, NUM_CLASSES)), jnp.float32
    )
    params["fc_b"] = jnp.zeros((NUM_CLASSES,), jnp.float32)
    return params


def loss_fn(params, x, y):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


@jax.jit
def train_step(params, momt, x, y, lr=0.01, beta=0.9):
    grads = jax.grad(loss_fn)(params, x, y)
    new_m, new_p = {}, {}
    for k in params:
        new_m[k] = beta * momt[k] + grads[k]
        new_p[k] = params[k] - lr * new_m[k]
    return new_p, new_m


def accuracy(params, x, y, batch=250):
    hits = 0
    for i in range(0, x.shape[0], batch):
        logits = forward(params, x[i:i + batch])
        hits += int(jnp.sum(jnp.argmax(logits, axis=1) == y[i:i + batch]))
    return hits / x.shape[0]


def train(steps=400, batch=100, seed=0, log=print):
    (xtr, ytr), (xte, yte) = make_dataset()
    params = init_params(seed)
    momt = {k: jnp.zeros_like(v) for k, v in params.items()}
    rng = np.random.default_rng(seed + 1)
    xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr)
    for step in range(steps):
        idx = rng.integers(0, xtr.shape[0], batch)
        params, momt = train_step(params, momt, xtr_j[idx], ytr_j[idx])
        if log and (step + 1) % 100 == 0:
            log(f"  cnn train step {step + 1}/{steps} "
                f"loss={float(loss_fn(params, xtr_j[idx], ytr_j[idx])):.4f}")
    acc = accuracy(params, jnp.asarray(xte), jnp.asarray(yte))
    if log:
        log(f"  cnn test accuracy: {acc:.4f}")
    return params, (xte, yte), acc


def export(outdir, log=print):
    """Train and dump weights + test set + metadata for the Rust engine."""
    import json
    import os

    from .tensorio import save_tensor

    os.makedirs(outdir, exist_ok=True)
    params, (xte, yte), acc = train(log=log)
    names = []
    for k, v in params.items():
        save_tensor(os.path.join(outdir, f"{k}.cstn"), np.asarray(v))
        names.append(k)
    save_tensor(os.path.join(outdir, "test_images.cstn"), xte)
    save_tensor(os.path.join(outdir, "test_labels.cstn"), yte)
    meta = {
        "tensors": names,
        "test_accuracy": acc,
        "img": IMG,
        "num_classes": NUM_CLASSES,
        "conv_specs": [[n, ci, co] for n, ci, co in CONV_SPECS],
    }
    with open(os.path.join(outdir, "cnn_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta
