# AOT artifact builder: lowers every Layer-2 graph variant to HLO *text*
# (NOT .serialize() — xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id
# protos; the text parser reassigns ids) plus a manifest.json that the Rust
# runtime's artifact registry consumes.
#
#   python -m python.compile.aot --out artifacts
#
# Runs once per source change (`make artifacts`); the request path is pure
# Rust afterwards.

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

LONUM = 32                      # default / CNN tile size
LONUMS = [32, 128]              # 128 = MXU-native tile, used by the benches
SQUARE_SIZES = [256, 512, 1024, 2048]
# Tile-GEMM batch buckets per LoNum (bounded by buffer size: 3·b·L²·4 B).
TILE_BATCHES = {32: [64, 256, 1024], 128: [16, 64, 256]}
PRECISIONS = ["f32", "bf16"]
# Rectangular GEMM shapes of the case-study CNN's im2col convolutions
# (weights (C_out, C_in·9) @ patches (C_in·9, batch·H·W) at batch=100).
CNN_GEMMS = [
    ("conv1", 64, 9, 25600),
    ("conv2", 64, 576, 6400),
    ("conv3", 128, 576, 1600),
]


def f32(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_meta(s):
    return {"shape": list(s.shape), "dtype": "f32"}


def build_specs():
    """The full artifact grid: (name, fn, example_args, metadata)."""
    specs = []

    def add(name, kind, fn, args, **params):
        specs.append(
            {
                "name": name,
                "kind": kind,
                "fn": fn,
                "args": args,
                "params": params,
            }
        )

    # --- get-norm kernel, square synthesized/ergo matrices ----------------
    for lonum in LONUMS:
        for n in SQUARE_SIZES:
            if n % lonum:
                continue
            add(
                f"getnorm_n{n}_l{lonum}", "getnorm",
                functools.partial(model.getnorm_graph, lonum=lonum),
                (f32(n, n),), n=n, lonum=lonum, precision="f32",
            )
            add(
                f"getnorm_mxu_n{n}_l{lonum}", "getnorm",
                functools.partial(model.getnorm_mxu_graph, lonum=lonum),
                (f32(n, n),), n=n, lonum=lonum, precision="bf16",
            )

    # --- batched tile GEMM (coordinator execution vehicle) ----------------
    for lonum in LONUMS:
        for b in TILE_BATCHES[lonum]:
            for prec in PRECISIONS:
                add(
                    f"tilegemm_l{lonum}_b{b}_{prec}", "tilegemm",
                    functools.partial(model.tile_gemm_graph, precision=prec),
                    (f32(b, lonum, lonum), f32(b, lonum, lonum)),
                    batch=b, lonum=lonum, precision=prec,
                )

    # --- dense GEMM baseline (cuBLAS stand-in) ----------------------------
    for n in SQUARE_SIZES:
        for prec in PRECISIONS:
            add(
                f"dense_n{n}_{prec}", "dense",
                functools.partial(model.dense_graph, precision=prec),
                (f32(n, n), f32(n, n)), m=n, k=n, n=n, precision=prec,
            )

    # --- fused single-call SpAMM (numerics oracle / small problems) -------
    for n in [256, 512]:
        for prec in PRECISIONS:
            add(
                f"spamm_fused_n{n}_{prec}", "spamm_fused",
                functools.partial(
                    model.spamm_fused_graph, lonum=LONUM, precision=prec
                ),
                (f32(n, n), f32(n, n), f32()),
                n=n, lonum=LONUM, precision=prec,
            )

    # --- τ tuning kernel (§3.5.2) ------------------------------------------
    bdims = sorted({n // l for n in SQUARE_SIZES for l in LONUMS if n % l == 0})
    for bdim in bdims:
        add(
            f"tune_b{bdim}", "tune",
            functools.partial(model.tune_graph, iters=20),
            (f32(bdim, bdim), f32(bdim, bdim), f32()),
            bdim=bdim, iters=20,
        )

    # --- CNN case-study conv GEMMs (dense baselines, rectangular) ---------
    for layer, m, k, n in CNN_GEMMS:
        for prec in PRECISIONS:
            add(
                f"dense_{layer}_{m}x{k}x{n}_{prec}", "dense",
                functools.partial(model.dense_graph, precision=prec),
                (f32(m, k), f32(k, n)), m=m, k=k, n=n, precision=prec,
                layer=layer,
            )

    return specs


def lower_spec(spec):
    lowered = jax.jit(spec["fn"]).lower(*spec["args"])
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--skip-cnn", action="store_true",
                    help="skip CNN training (kernel artifacts only)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    specs = build_specs()
    manifest = {"lonum": LONUM, "version": 1, "artifacts": []}
    for i, spec in enumerate(specs):
        fname = f"{spec['name']}.hlo.txt"
        path = os.path.join(args.out, fname)
        text = lower_spec(spec)
        with open(path, "w") as f:
            f.write(text)
        n_outputs = len(jax.eval_shape(spec["fn"], *spec["args"]))
        manifest["artifacts"].append(
            {
                "name": spec["name"],
                "kind": spec["kind"],
                "file": fname,
                "inputs": [shape_meta(s) for s in spec["args"]],
                "n_outputs": n_outputs,
                "params": spec["params"],
            }
        )
        print(f"[{i + 1}/{len(specs)}] {fname} ({len(text)} chars)")

    if not args.skip_cnn:
        print("training case-study CNN ...")
        from . import cnn

        meta = cnn.export(os.path.join(args.out, "cnn"))
        manifest["cnn"] = {
            "dir": "cnn",
            "test_accuracy": meta["test_accuracy"],
            "conv_specs": meta["conv_specs"],
            "img": meta["img"],
            "num_classes": meta["num_classes"],
        }

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out}/")


if __name__ == "__main__":
    sys.exit(main())
