# Layer-1 Pallas kernels for cuSpAMM-rs.
#
# All kernels are authored for the TPU memory model (VMEM tiles, MXU matmul)
# but are lowered with interpret=True so the resulting HLO runs on any PJRT
# backend, including the Rust CPU client on the request path.  See
# DESIGN.md §4 (hardware adaptation) for the CUDA→TPU mapping.

from .get_norm import get_norm, get_norm_mxu
from .multiply import spamm_multiply
from .tile_gemm import tile_gemm_batch
from .tune import tune_tau

__all__ = [
    "get_norm",
    "get_norm_mxu",
    "spamm_multiply",
    "tile_gemm_batch",
    "tune_tau",
]
