# Layer-1 Pallas: the paper's *get-norm* kernel (cuSpAMM §3.2).
#
# Computes the Frobenius norm of every LoNum×LoNum sub-matrix (tile) of the
# input, producing the `normmap` array used by the multiplication kernel to
# decide which tile products satisfy ‖A[i,k]‖·‖B[k,j]‖ ≥ τ.
#
# CUDA → TPU adaptation (DESIGN.md §4):
#   * paper: one threadblock per tile, per-thread squares staged in shared
#     memory, bank-conflict-free tree reduction.
#   * here: one Pallas grid program per tile; the tile is a VMEM block
#     (BlockSpec), the reduction is a single VPU 2-D reduce — there are no
#     shared-memory banks to conflict on.
#   * paper's tensor-core reduction (Eq. 3/4: D = 1·X, D' = D·1) maps to two
#     MXU matmuls with bf16 inputs and f32 accumulation (`get_norm_mxu`).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _get_norm_kernel(a_ref, o_ref):
    """One grid program: F-norm of one LoNum×LoNum tile via VPU reduce."""
    t = a_ref[...].astype(jnp.float32)
    o_ref[0, 0] = jnp.sqrt(jnp.sum(t * t))


def _get_norm_mxu_kernel(a_ref, o_ref):
    """Paper Eq. 3/4 ones-matmul reduction on the MXU.

    The squares are formed in bf16 (mirroring the paper's fp16 tensor-core
    inputs) and both matmuls accumulate in f32 (`preferred_element_type`),
    which is exactly the tensor-core MMA contract the paper relies on.
    """
    x = a_ref[...].astype(jnp.bfloat16)
    sq = (x * x).astype(jnp.bfloat16)
    m = sq.shape[0]
    ones = jnp.ones((m, m), dtype=jnp.bfloat16)
    # Eq. 3: column sums into every row; Eq. 4: row sums of that — every
    # element of d2 is the full tile reduction, we read [0, 0].
    d1 = jax.lax.dot(ones, sq, preferred_element_type=jnp.float32)
    d2 = jax.lax.dot(
        d1.astype(jnp.bfloat16), ones, preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = jnp.sqrt(d2[0, 0])


def _build(kernel, rows, cols, lonum, interpret):
    if rows % lonum or cols % lonum:
        raise ValueError(
            f"matrix {rows}x{cols} not divisible by LoNum={lonum}; pad first"
        )
    bdim_r, bdim_c = rows // lonum, cols // lonum
    return pl.pallas_call(
        kernel,
        grid=(bdim_r, bdim_c),
        in_specs=[pl.BlockSpec((lonum, lonum), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bdim_r, bdim_c), jnp.float32),
        interpret=interpret,
    )


def _make_block_norm_kernel(rows, cols, lonum, mxu):
    """Whole-matrix single-program variant for the CPU-PJRT export shape
    (interpret-mode grid steps cost ~2 ms each; DESIGN.md §Perf).  Computes
    every tile norm with one reshaped reduction.  The mxu flavour casts the
    squares to bf16 and accumulates in f32 — same contract as Eq. 3/4 on
    the MXU."""
    br, bc = rows // lonum, cols // lonum

    def kernel(a_ref, o_ref):
        x = a_ref[...]
        if mxu:
            xb = x.astype(jnp.bfloat16)
            sq = (xb * xb).astype(jnp.bfloat16)
        else:
            sq = x * x
        t = sq.reshape(br, lonum, bc, lonum)
        s = jnp.sum(t.astype(jnp.float32), axis=(1, 3), dtype=jnp.float32)
        o_ref[...] = jnp.sqrt(s)

    return kernel


def _build_block(rows, cols, lonum, mxu, interpret):
    if rows % lonum or cols % lonum:
        raise ValueError(
            f"matrix {rows}x{cols} not divisible by LoNum={lonum}; pad first"
        )
    br, bc = rows // lonum, cols // lonum
    return pl.pallas_call(
        _make_block_norm_kernel(rows, cols, lonum, mxu),
        grid=(1,),
        in_specs=[pl.BlockSpec((rows, cols), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, bc), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((br, bc), jnp.float32),
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("lonum", "interpret", "block"))
def get_norm(a, *, lonum=32, interpret=True, block=False):
    """normmap[i, j] = ‖a[i·LoNum:(i+1)·LoNum, j·LoNum:(j+1)·LoNum]‖_F (f32).

    block=False is the TPU-shaped per-tile grid kernel; block=True is the
    single-program CPU-PJRT export shape (numerically identical).
    """
    if block:
        return _build_block(a.shape[0], a.shape[1], lonum, False, interpret)(a)
    return _build(_get_norm_kernel, a.shape[0], a.shape[1], lonum, interpret)(a)


@functools.partial(jax.jit, static_argnames=("lonum", "interpret", "block"))
def get_norm_mxu(a, *, lonum=32, interpret=True, block=False):
    """Mixed-precision normmap using the paper's MMA ones-matmul reduction."""
    if block:
        return _build_block(a.shape[0], a.shape[1], lonum, True, interpret)(a)
    return _build(_get_norm_mxu_kernel, a.shape[0], a.shape[1], lonum, interpret)(a)
