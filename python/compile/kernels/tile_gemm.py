# Layer-1 Pallas: batched tile GEMM — the execution vehicle for the Rust
# coordinator's compacted schedule.
#
# The paper compacts the bitmap into `map_offset` *inside* the multiplication
# kernel so that valid tile products are visited contiguously (Fig. 3b).  On
# our PJRT-CPU substrate a masked kernel cannot actually skip work, so the
# compaction lives in the Rust coordinator (spamm::schedule), which gathers
# the valid (A[i,k], B[k,j]) tile pairs into a dense batch and runs this
# kernel — contiguity re-appears as the batch dimension.  Time is then
# genuinely proportional to the number of valid products, which is the
# algorithmic property the paper's Fig. 3(b) optimization protects.
#
# The bf16 variant is the Alg. 3 tensor-core analog: operands cast to bf16,
# MXU dot with f32 accumulation.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(precision):
    def kernel(a_ref, b_ref, o_ref):
        a = a_ref[0]
        b = b_ref[0]
        if precision == "bf16":
            a = a.astype(jnp.bfloat16)
            b = b.astype(jnp.bfloat16)
        o_ref[0] = jax.lax.dot(a, b, preferred_element_type=jnp.float32)

    return kernel


def _make_block_kernel(precision):
    """Whole batch in one VMEM block, batched MXU dot inside the program.

    Interpret-mode grid steps cost ~2 ms each on CPU-PJRT (measured; see
    DESIGN.md §Perf), so the exported artifacts collapse the grid: one
    program, one batched dot_general.  On a real TPU the per-tile grid
    variant above is the right shape (3·L²·4 B per step in VMEM); both are
    numerically identical and the tests pin that.
    """

    def kernel(a_ref, b_ref, o_ref):
        a = a_ref[...]
        b = b_ref[...]
        if precision == "bf16":
            a = a.astype(jnp.bfloat16)
            b = b.astype(jnp.bfloat16)
        o_ref[...] = jax.lax.dot_general(
            a,
            b,
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )

    return kernel


@functools.partial(
    jax.jit, static_argnames=("precision", "interpret", "block")
)
def tile_gemm_batch(a_tiles, b_tiles, *, precision="f32", interpret=True,
                    block=False):
    """(batch, L, L) @ (batch, L, L) → (batch, L, L), f32 in/out.

    block=False: one grid program per batch element; each program holds one
    A tile, one B tile and the product tile in VMEM (3·L²·4 bytes — L=128
    is still only 192 KiB, comfortably inside a TPU core's ~16 MiB VMEM).
    This is the TPU-shaped kernel.

    block=True: single program over the whole batch — the CPU-PJRT export
    shape (see _make_block_kernel).
    """
    batch, lonum, _ = a_tiles.shape
    if a_tiles.shape != b_tiles.shape:
        raise ValueError(f"shape mismatch {a_tiles.shape} vs {b_tiles.shape}")
    if block:
        return pl.pallas_call(
            _make_block_kernel(precision),
            grid=(1,),
            in_specs=[
                pl.BlockSpec((batch, lonum, lonum), lambda i: (0, 0, 0)),
                pl.BlockSpec((batch, lonum, lonum), lambda i: (0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((batch, lonum, lonum), lambda i: (0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((batch, lonum, lonum), jnp.float32),
            interpret=interpret,
        )(a_tiles, b_tiles)
    return pl.pallas_call(
        _make_kernel(precision),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, lonum, lonum), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lonum, lonum), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lonum, lonum), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, lonum, lonum), jnp.float32),
        interpret=interpret,
    )(a_tiles, b_tiles)
