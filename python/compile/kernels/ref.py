# Pure-jnp correctness oracles for every Layer-1 kernel.
#
# Two independent SpAMM references:
#   * `spamm_flat`   — the flat two-kernel reformulation (cuSpAMM §3.1)
#   * `spamm_recursive` — the original quad-tree Algorithm 1 of
#     Challacombe & Bock, recursion cut off at LoNum.
# The paper *asserts* the two are equivalent; python/tests/test_equivalence.py
# proves it on swept inputs.

import numpy as np
import jax.numpy as jnp


def tile_norms(a, lonum):
    """normmap[i, j] = ‖tile(i, j)‖_F, computed by reshape, in f64 then f32."""
    rows, cols = a.shape
    assert rows % lonum == 0 and cols % lonum == 0, (a.shape, lonum)
    br, bc = rows // lonum, cols // lonum
    t = np.asarray(a, np.float64).reshape(br, lonum, bc, lonum)
    sq = np.sum(t**2, axis=(1, 3))
    return jnp.asarray(np.sqrt(sq), jnp.float32)


def spamm_flat(a, b, tau, lonum, a_normmap=None, b_normmap=None):
    """Flat SpAMM: mask tile products by the norm threshold, then multiply.

    C[i, j] = Σ_k  A[i, k] @ B[k, j] · [ ‖A[i,k]‖·‖B[k,j]‖ ≥ τ ]
    """
    n = a.shape[0]
    bdim = n // lonum
    na = tile_norms(a, lonum) if a_normmap is None else a_normmap
    nb = tile_norms(b, lonum) if b_normmap is None else b_normmap
    at = jnp.asarray(a, jnp.float32).reshape(bdim, lonum, bdim, lonum).transpose(0, 2, 1, 3)
    bt = jnp.asarray(b, jnp.float32).reshape(bdim, lonum, bdim, lonum).transpose(0, 2, 1, 3)
    c = jnp.zeros((bdim, bdim, lonum, lonum), jnp.float32)
    mask = (na[:, :, None] * nb[None, :, :]) >= tau  # [i, k, j]
    # einsum with a mask on the k contraction per (i, j): materialize masked
    # products tile-by-tile (oracle clarity over speed).
    for i in range(bdim):
        for j in range(bdim):
            acc = jnp.zeros((lonum, lonum), jnp.float32)
            for k in range(bdim):
                acc = acc + jnp.where(mask[i, k, j], at[i, k] @ bt[k, j], 0.0)
            c = c.at[i, j].set(acc)
    return c.transpose(0, 2, 1, 3).reshape(n, n)


def spamm_recursive(a, b, tau, lonum):
    """Original SpAMM (Algorithm 1): quad-tree recursion, cut off at LoNum."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)

    def rec(a_, b_):
        n = a_.shape[0]
        if n <= lonum:
            return a_ @ b_
        h = n // 2
        aq = [[a_[:h, :h], a_[:h, h:]], [a_[h:, :h], a_[h:, h:]]]
        bq = [[b_[:h, :h], b_[:h, h:]], [b_[h:, :h], b_[h:, h:]]]
        c = np.zeros_like(a_)
        cq = [[c[:h, :h], c[:h, h:]], [c[h:, :h], c[h:, h:]]]
        for i in range(2):
            for j in range(2):
                acc = np.zeros((h, h), np.float32)
                for k in range(2):
                    if np.linalg.norm(aq[i][k]) * np.linalg.norm(bq[k][j]) >= tau:
                        acc += rec(aq[i][k], bq[k][j])
                cq[i][j][...] = acc
        return c

    return rec(a, b)


def dense(a, b):
    """Exact dense GEMM reference (f32 accumulate)."""
    return jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32)


def tile_gemm_batch(a_tiles, b_tiles):
    """Batched tile product oracle."""
    return jnp.einsum(
        "bij,bjk->bik",
        jnp.asarray(a_tiles, jnp.float32),
        jnp.asarray(b_tiles, jnp.float32),
    )


def valid_ratio(a_normmap, b_normmap, tau):
    prod = np.asarray(a_normmap)[:, :, None] * np.asarray(b_normmap)[None, :, :]
    return float(np.mean(prod >= tau))
