# Layer-1 Pallas: the paper's *multiplication* kernel (cuSpAMM §3.3, Alg. 2/3).
#
# Flat (non-recursive) SpAMM: for every output tile C[i,j], accumulate
# A[i,k] @ B[k,j] over k, but only for k where the norm product passes the
# threshold:  ‖A[i,k]‖_F · ‖B[k,j]‖_F ≥ τ   (the paper's `bitmap[k]`).
#
# CUDA → TPU adaptation (DESIGN.md §4):
#   * paper: threadblock per C tile, bitmap + map_offset in shared memory,
#     double-buffered tile loads, first/second half-block prefetch overlap.
#   * here: grid (i, j, k) with k innermost; the tile loads are VMEM blocks
#     scheduled by BlockSpec index maps (on a real TPU the Mosaic pipeliner
#     performs the double buffering the paper hand-codes); the bitmap test
#     becomes a `pl.when` predicate on the current k step.
#   * Alg. 3 (tensor core): `precision="bf16"` casts the operands to bf16 and
#     accumulates in f32 via `preferred_element_type` — the MXU analog of
#     fp16 MMA fragments with an f32 accumulator fragment.
#
# NOTE ON WORK SKIPPING: under interpret=True on a CPU backend the masked
# branch is still *scheduled* (select semantics), so this fused kernel is the
# semantics/numerics vehicle.  The genuinely-skipping execution path is the
# Rust coordinator + `tile_gemm_batch` (see DESIGN.md §2, row 3).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(bdim, precision):
    def kernel(tau_ref, na_ref, nb_ref, a_ref, b_ref, o_ref, acc_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _zero():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # The paper's bitmap[k] test: norm product against τ.
        norm_mul = na_ref[i, k] * nb_ref[k, j]

        @pl.when(norm_mul >= tau_ref[0, 0])
        def _accum():
            if precision == "bf16":
                a = a_ref[...].astype(jnp.bfloat16)
                b = b_ref[...].astype(jnp.bfloat16)
            else:
                a = a_ref[...]
                b = b_ref[...]
            acc_ref[...] += jax.lax.dot(
                a, b, preferred_element_type=jnp.float32
            )

        @pl.when(k == bdim - 1)
        def _store():
            o_ref[...] = acc_ref[...]

    return kernel


def _make_block_kernel(bdim, lonum, precision):
    """Single-program variant for the CPU-PJRT export shape (interpret-mode
    grid steps cost ~2 ms each; DESIGN.md §Perf).

    Computes every tile product with one batched contraction and applies
    the bitmap as a mask on the k-sum.  On a real TPU the per-(i,j,k) grid
    kernel above is the right shape — and there `pl.when` genuinely skips
    the masked MXU work, which this dense-compute variant does not (the
    *skipping* execution path on this testbed is the Rust coordinator +
    tile_gemm batches).
    """

    def kernel(tau_ref, na_ref, nb_ref, a_ref, b_ref, o_ref):
        a = a_ref[...]
        b = b_ref[...]
        if precision == "bf16":
            a = a.astype(jnp.bfloat16)
            b = b.astype(jnp.bfloat16)
        a4 = a.reshape(bdim, lonum, bdim, lonum)  # (i, r, k, s)
        b4 = b.reshape(bdim, lonum, bdim, lonum)  # (k, s, j, t)
        # every tile product T[i,k,j,r,t] = A[i,k] @ B[k,j]
        t = jnp.einsum(
            "irks,ksjt->ikjrt", a4, b4, preferred_element_type=jnp.float32
        )
        mask = (
            na_ref[...][:, :, None] * nb_ref[...][None, :, :]
            >= tau_ref[0, 0]
        ).astype(jnp.float32)
        c4 = jnp.einsum("ikjrt,ikj->irjt", t, mask)
        o_ref[...] = c4.reshape(bdim * lonum, bdim * lonum)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("lonum", "precision", "interpret", "block")
)
def spamm_multiply(a, b, a_normmap, b_normmap, tau, *, lonum=32,
                   precision="f32", interpret=True, block=False):
    """Masked SpAMM product C = A ⊛_τ B for square inputs.

    Args:
      a, b: f32[N, N] with N divisible by `lonum`.
      a_normmap, b_normmap: f32[BDIM, BDIM] tile F-norms (from get_norm).
      tau: f32 scalar (traced) — the approximation threshold.
      precision: "f32" (cublasSgemm analog) or "bf16" (tensor-core analog).
    Returns:
      f32[N, N].
    """
    n = a.shape[0]
    if a.shape != b.shape or a.shape[0] != a.shape[1]:
        raise ValueError(f"square same-shape inputs required, got {a.shape} {b.shape}")
    if n % lonum:
        raise ValueError(f"N={n} not divisible by LoNum={lonum}")
    bdim = n // lonum
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)

    if block:
        return pl.pallas_call(
            _make_block_kernel(bdim, lonum, precision),
            grid=(1,),
            in_specs=[
                pl.BlockSpec((1, 1), lambda i: (0, 0)),
                pl.BlockSpec((bdim, bdim), lambda i: (0, 0)),
                pl.BlockSpec((bdim, bdim), lambda i: (0, 0)),
                pl.BlockSpec((n, n), lambda i: (0, 0)),
                pl.BlockSpec((n, n), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
            interpret=interpret,
        )(tau_arr, a_normmap, b_normmap, a, b)

    grid = (bdim, bdim, bdim)
    return pl.pallas_call(
        _make_kernel(bdim, precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),          # tau
            pl.BlockSpec((bdim, bdim), lambda i, j, k: (0, 0)),    # normmap A
            pl.BlockSpec((bdim, bdim), lambda i, j, k: (0, 0)),    # normmap B
            pl.BlockSpec((lonum, lonum), lambda i, j, k: (i, k)),  # A tile
            pl.BlockSpec((lonum, lonum), lambda i, j, k: (k, j)),  # B tile
        ],
        out_specs=pl.BlockSpec((lonum, lonum), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        # f32 VMEM accumulator — the paper's per-block register/SMEM
        # accumulator (and Alg. 3's f32 `ab_frag` accumulator fragment).
        scratch_shapes=[pltpu.VMEM((lonum, lonum), jnp.float32)],
        interpret=interpret,
    )(tau_arr, a_normmap, b_normmap, a, b)
