# Layer-1/2: in-graph τ search for a target *valid ratio* (cuSpAMM §3.5.2).
#
# valid_ratio(τ) = (# tile products with ‖A[i,k]‖·‖B[k,j]‖ ≥ τ) / BDIM³.
# Given a user target the paper searches τ by binary search over
# [0, k·ave] where `ave` is the mean norm product, expanding k whenever the
# upper bound cannot satisfy the demand.  This file implements the identical
# procedure as a lowerable JAX graph (lax.while_loop) over precomputed
# normmaps, so the Rust runtime can run it on-device; a host-side Rust twin
# lives in rust/src/spamm/tuner.rs.

import functools

import jax
import jax.numpy as jnp


def valid_ratio(a_normmap, b_normmap, tau):
    """Fraction of (i, k, j) tile products passing the τ test."""
    # prod[i, k, j] = na[i, k] * nb[k, j]
    prod = a_normmap[:, :, None] * b_normmap[None, :, :]
    return jnp.mean((prod >= tau).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("iters",))
def tune_tau(a_normmap, b_normmap, target_ratio, *, iters=20):
    """Expanding binary search for τ s.t. valid_ratio(τ) ≈ target_ratio.

    Returns (tau, achieved_ratio).  Matches §3.5.2: initial upper bound is
    `ave` (the mean norm product, k=1); while the bound cannot reach below
    the target ratio, k ← k+1; then `iters` bisection steps.
    """
    prod = a_normmap[:, :, None] * b_normmap[None, :, :]
    total = jnp.float32(prod.size)
    ave = jnp.mean(prod)
    target = jnp.asarray(target_ratio, jnp.float32)

    def ratio_at(tau):
        return jnp.sum((prod >= tau).astype(jnp.float32)) / total

    # Expansion phase: grow the upper bound k·ave until the ratio there is
    # at or below the target (i.e. the bracket contains the answer).
    def exp_cond(state):
        k, _ = state
        return jnp.logical_and(ratio_at(k * ave) > target, k < 1024.0)

    def exp_body(state):
        k, _ = state
        return (k + 1.0, ratio_at((k + 1.0) * ave))

    k, _ = jax.lax.while_loop(exp_cond, exp_body, (jnp.float32(1.0), ratio_at(ave)))

    # Bisection phase.
    def bis_body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        r = ratio_at(mid)
        # ratio decreases with τ: too many valid → raise lo to mid.
        lo = jnp.where(r > target, mid, lo)
        hi = jnp.where(r > target, hi, mid)
        return (lo, hi)

    lo, hi = jax.lax.fori_loop(
        0, iters, bis_body, (jnp.float32(0.0), k * ave)
    )
    tau = 0.5 * (lo + hi)
    return tau, ratio_at(tau)
