# Layer-2: exportable JAX compute graphs composing the Layer-1 kernels.
#
# Every public function here is a *variant template*: `aot.py` instantiates
# it for concrete shapes/dtypes and lowers it to HLO text that the Rust
# runtime loads (one compiled executable per variant).  Nothing in this file
# runs on the request path.

import jax
import jax.numpy as jnp

from .kernels import get_norm, get_norm_mxu, spamm_multiply, tile_gemm_batch
from .kernels.tune import tune_tau


def getnorm_graph(a, *, lonum=32):
    """normmap of a (rows×cols f32) matrix — the get-norm kernel (f32 path)."""
    return (get_norm(a, lonum=lonum, block=True),)


def getnorm_mxu_graph(a, *, lonum=32):
    """Mixed-precision normmap via the MXU ones-matmul reduction (Eq. 3/4)."""
    return (get_norm_mxu(a, lonum=lonum, block=True),)


def tile_gemm_graph(a_tiles, b_tiles, *, precision="f32"):
    """Batched tile products for the coordinator's compacted schedule."""
    return (tile_gemm_batch(a_tiles, b_tiles, precision=precision, block=True),)


def spamm_fused_graph(a, b, tau, *, lonum=32, precision="f32"):
    """Whole SpAMM in one graph: get-norm (both inputs) + masked multiply.

    Used for single-call execution of small problems and as the on-device
    numerics oracle for the coordinator path.
    """
    if precision == "bf16":
        na = get_norm_mxu(a, lonum=lonum, block=True)
        nb = get_norm_mxu(b, lonum=lonum, block=True)
    else:
        na = get_norm(a, lonum=lonum, block=True)
        nb = get_norm(b, lonum=lonum, block=True)
    c = spamm_multiply(a, b, na, nb, tau, lonum=lonum, precision=precision, block=True)
    return (c,)


def dense_graph(a, b, *, precision="f32"):
    """Dense GEMM baseline — the cuBLAS stand-in, same runtime, same dot.

    The bf16 variant mirrors cublasHgemm-with-tensor-cores: operands cast to
    bf16, f32 accumulation.
    """
    if precision == "bf16":
        a = a.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
    return (jax.lax.dot(a, b, preferred_element_type=jnp.float32),)


def tune_graph(a_normmap, b_normmap, target_ratio, *, iters=20):
    """valid-ratio → τ search (§3.5.2) over precomputed normmaps."""
    tau, ratio = tune_tau(a_normmap, b_normmap, target_ratio, iters=iters)
    return (tau, ratio)
