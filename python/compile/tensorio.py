# Minimal binary tensor interchange between the Python build path and the
# Rust runtime (weights, fixtures, datasets).  Deliberately trivial:
#
#   magic   : 4 bytes  b"CSTN"
#   version : u32 LE   (1)
#   dtype   : u32 LE   (0 = f32, 1 = i32)
#   ndim    : u32 LE
#   dims    : ndim × u32 LE
#   data    : row-major little-endian payload
#
# Rust twin: rust/src/matrix/tensorio.rs.

import struct

import numpy as np

MAGIC = b"CSTN"
_DTYPES = {0: np.float32, 1: np.int32}
_DTYPE_IDS = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def save_tensor(path, arr):
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _DTYPE_IDS:
        arr = arr.astype(np.float32)
    did = _DTYPE_IDS[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<III", 1, did, arr.ndim))
        f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
        f.write(arr.tobytes())


def load_tensor(path):
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, did, ndim = struct.unpack("<III", f.read(12))
        if version != 1:
            raise ValueError(f"{path}: unsupported version {version}")
        dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
        data = np.frombuffer(f.read(), dtype=_DTYPES[did])
        return data.reshape(dims).copy()
