import numpy as np
import pytest


def decay_matrix(n, kind="algebraic", c=0.1, lam=0.1, seed=0, noise=True):
    """Synthesized decay matrices per cuSpAMM §4.1.

    algebraic: |a_ij| ≤ c/(|i−j|^λ + 1)   (the paper's synthesized dataset)
    exponential: |a_ij| ≤ c·λ^|i−j|        (the ergo-like dataset)
    """
    idx = np.abs(np.subtract.outer(np.arange(n), np.arange(n))).astype(np.float64)
    if kind == "algebraic":
        env = c / (idx**lam + 1.0)
    elif kind == "exponential":
        env = c * np.power(lam, idx)
    else:
        raise ValueError(kind)
    if noise:
        rng = np.random.default_rng(seed)
        env = env * rng.uniform(-1.0, 1.0, (n, n))
    return env.astype(np.float32)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
