# L2 graph composition + AOT export pipeline sanity.
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from python.compile import model, aot
from python.compile.kernels import ref
from .conftest import decay_matrix


def test_spamm_fused_graph_matches_oracle():
    a = decay_matrix(128, seed=21)
    b = decay_matrix(128, seed=22)
    nm = np.asarray(ref.tile_norms(a, 32))
    tau = float(np.median(nm)) ** 2
    (c,) = model.spamm_fused_graph(a, b, jnp.float32(tau), lonum=32)
    want = np.asarray(ref.spamm_flat(a, b, tau, 32))
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-4, atol=1e-6)


def test_dense_graph_is_exact():
    a = decay_matrix(64, seed=23)
    b = decay_matrix(64, seed=24)
    (c,) = model.dense_graph(a, b)
    np.testing.assert_allclose(np.asarray(c), a @ b, rtol=1e-5, atol=1e-6)


def test_dense_graph_bf16_casts():
    a = decay_matrix(64, seed=25)
    b = decay_matrix(64, seed=26)
    (c,) = model.dense_graph(a, b, precision="bf16")
    assert np.asarray(c).dtype == np.float32
    rel = np.linalg.norm(np.asarray(c) - a @ b) / np.linalg.norm(a @ b)
    assert rel < 2e-2


def test_specs_lower_to_hlo_text():
    """Every artifact spec must lower to parseable non-trivial HLO text."""
    specs = aot.build_specs()
    names = {s["name"] for s in specs}
    assert len(names) == len(specs), "duplicate artifact names"
    # Lower a representative subset (full grid runs in `make artifacts`).
    for spec in specs[:2] + specs[-2:]:
        text = aot.lower_spec(spec)
        assert text.startswith("HloModule"), spec["name"]
        assert "ROOT" in text


def test_manifest_written(tmp_path):
    """Smoke the aot CLI on a single tiny spec grid (monkeypatched sizes)."""
    import python.compile.aot as aot_mod

    old = (
        aot_mod.SQUARE_SIZES,
        aot_mod.TILE_BATCHES,
        aot_mod.CNN_GEMMS,
        aot_mod.LONUMS,
    )
    aot_mod.SQUARE_SIZES = [64]
    aot_mod.TILE_BATCHES = {32: [4]}
    aot_mod.CNN_GEMMS = []
    aot_mod.LONUMS = [32]
    try:
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out", str(tmp_path), "--skip-cnn"]
        try:
            aot_mod.main()
        finally:
            sys.argv = argv
    finally:
        (
            aot_mod.SQUARE_SIZES,
            aot_mod.TILE_BATCHES,
            aot_mod.CNN_GEMMS,
            aot_mod.LONUMS,
        ) = old
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["lonum"] == 32
    for art in manifest["artifacts"]:
        assert os.path.exists(tmp_path / art["file"])
        assert art["n_outputs"] >= 1


def test_tune_graph_outputs():
    na = np.abs(np.random.default_rng(0).standard_normal((8, 8))).astype(np.float32)
    tau, ratio = model.tune_graph(na, na, jnp.float32(0.25))
    assert 0.0 <= float(ratio) <= 1.0
