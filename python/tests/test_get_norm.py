# get-norm kernel vs pure-jnp/numpy oracle.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from python.compile.kernels import get_norm, get_norm_mxu
from python.compile.kernels import ref
from .conftest import decay_matrix


@pytest.mark.parametrize("n,lonum", [(32, 32), (64, 32), (128, 32), (128, 64), (256, 32)])
def test_get_norm_matches_ref(n, lonum, rng):
    a = rng.standard_normal((n, n)).astype(np.float32)
    got = np.asarray(get_norm(a, lonum=lonum))
    want = np.asarray(ref.tile_norms(a, lonum))
    assert got.shape == (n // lonum, n // lonum)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_get_norm_rectangular(rng):
    a = rng.standard_normal((64, 160)).astype(np.float32)
    got = np.asarray(get_norm(a, lonum=32))
    want = np.asarray(ref.tile_norms(a, 32))
    assert got.shape == (2, 5)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_get_norm_zero_matrix():
    a = np.zeros((64, 64), np.float32)
    assert np.all(np.asarray(get_norm(a, lonum=32)) == 0.0)


def test_get_norm_single_tile_is_fnorm(rng):
    a = rng.standard_normal((32, 32)).astype(np.float32)
    got = float(np.asarray(get_norm(a, lonum=32))[0, 0])
    assert got == pytest.approx(float(np.linalg.norm(a)), rel=1e-5)


def test_get_norm_indivisible_raises(rng):
    a = rng.standard_normal((48, 48)).astype(np.float32)
    with pytest.raises(ValueError):
        get_norm(a, lonum=32)


def test_get_norm_mxu_close_to_exact(rng):
    """bf16 ones-matmul reduction (Eq. 3/4): ~3 decimal digits, like fp16 MMA."""
    a = decay_matrix(128, seed=3)
    exact = np.asarray(ref.tile_norms(a, 32))
    got = np.asarray(get_norm_mxu(a, lonum=32))
    np.testing.assert_allclose(got, exact, rtol=2e-2, atol=1e-4)


def test_get_norm_decay_structure():
    """Decay matrices: diagonal tiles must dominate off-diagonal tiles."""
    a = decay_matrix(256, kind="exponential", c=1.0, lam=0.5, noise=False)
    nm = np.asarray(get_norm(a, lonum=32))
    diag = np.diag(nm)
    off = nm[0, -1]
    assert np.all(diag > off)


@settings(max_examples=20, deadline=None)
@given(
    bdim=st.integers(1, 4),
    lonum=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_get_norm_property(bdim, lonum, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((bdim * lonum, bdim * lonum)).astype(np.float32)
    got = np.asarray(get_norm(a, lonum=lonum))
    want = np.asarray(ref.tile_norms(a, lonum))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # Norm invariant: sum of squared tile norms == squared full F-norm.
    np.testing.assert_allclose(
        np.sum(got**2), np.linalg.norm(a) ** 2, rtol=1e-3
    )
