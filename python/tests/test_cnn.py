# Case-study CNN: im2col conv correctness + dataset/training sanity +
# tensorio round trip.
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from python.compile import cnn
from python.compile.tensorio import save_tensor, load_tensor


def test_im2col_matches_direct_conv(rng):
    """conv-as-GEMM must equal a direct convolution."""
    x = rng.standard_normal((2, 3, 8, 8)).astype(np.float32)
    w = rng.standard_normal((5, 3 * 9)).astype(np.float32)
    b = rng.standard_normal(5).astype(np.float32)
    got = np.asarray(cnn.conv_gemm(jnp.asarray(w), jnp.asarray(b), jnp.asarray(x)))
    # direct conv via jax.lax
    w4 = w.reshape(5, 3, 3, 3)
    want = jax.lax.conv_general_dilated(
        x, np.transpose(w4, (0, 1, 2, 3)), (1, 1), ((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ) + b[None, :, None, None]
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-4)


def test_im2col_shape():
    x = jnp.zeros((4, 8, 16, 16))
    cols = cnn.im2col(x)
    assert cols.shape == (8 * 9, 4 * 16 * 16)


def test_maxpool2():
    x = jnp.arange(16.0).reshape(1, 1, 4, 4)
    out = np.asarray(cnn.maxpool2(x))
    np.testing.assert_array_equal(out[0, 0], [[5.0, 7.0], [13.0, 15.0]])


def test_dataset_deterministic_and_balanced():
    (xtr, ytr), (xte, yte) = cnn.make_dataset(seed=7, n_train=500, n_test=100)
    (xtr2, _), _ = cnn.make_dataset(seed=7, n_train=500, n_test=100)
    np.testing.assert_array_equal(xtr, xtr2)
    assert xtr.shape == (500, 1, 16, 16)
    assert set(np.unique(ytr)) <= set(range(10))
    assert len(np.unique(yte)) == 10


def test_forward_shapes():
    params = cnn.init_params()
    x = jnp.zeros((3, 1, 16, 16))
    logits = cnn.forward(params, x)
    assert logits.shape == (3, 10)


def test_short_training_learns():
    """A tiny training run must beat chance decisively (dataset is easy)."""
    params, (xte, yte), acc = cnn.train(steps=120, batch=64, log=None)
    assert acc > 0.6


def test_relu_feature_sparsity():
    """The paper's premise: ReLU feature maps are ≥~50% zeros, making the
    im2col patch matrices near-sparse."""
    params, (xte, yte), _ = cnn.train(steps=120, batch=64, log=None)
    x = jnp.asarray(xte[:50])
    h = jax.nn.relu(cnn.conv_gemm(params["conv1_w"], params["conv1_b"], x))
    h = cnn.maxpool2(h)
    patches = np.asarray(cnn.im2col(h))
    zero_frac = float(np.mean(patches == 0.0))
    assert zero_frac > 0.3, zero_frac


def test_tensorio_roundtrip(tmp_path, rng):
    for arr in [
        rng.standard_normal((3, 4, 5)).astype(np.float32),
        np.arange(7, dtype=np.int32),
        np.float32(3.5).reshape(()),
    ]:
        p = tmp_path / "t.cstn"
        save_tensor(p, arr)
        back = load_tensor(p)
        assert back.dtype == arr.dtype
        np.testing.assert_array_equal(back, arr)


def test_tensorio_bad_magic(tmp_path):
    p = tmp_path / "bad.cstn"
    p.write_bytes(b"NOPE" + b"\0" * 16)
    with pytest.raises(ValueError):
        load_tensor(p)
