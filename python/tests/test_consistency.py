# Cross-layer consistency checks: the artifact grid must match what the
# CNN/model actually produce at runtime (these would catch shape drift
# between cnn.py and aot.py, or a manifest that lies about its kernels).
import numpy as np
import jax
import jax.numpy as jnp

from python.compile import aot, cnn, model


def test_cnn_gemm_shapes_match_architecture():
    """aot.CNN_GEMMS must equal the im2col GEMM shapes cnn.py produces at
    the export batch size (100 test images)."""
    batch = 100
    specs = {name: (cin, cout) for name, cin, cout in cnn.CONV_SPECS}
    # spatial dims per layer: conv1 on 16², conv2 on 8², conv3 on 4²
    spatial = {"conv1": 16, "conv2": 8, "conv3": 4}
    declared = {layer: (m, k, n) for layer, m, k, n in aot.CNN_GEMMS}
    assert set(declared) == set(specs)
    for layer, (cin, cout) in specs.items():
        s = spatial[layer]
        want = (cout, cin * 9, batch * s * s)
        assert declared[layer] == want, f"{layer}: {declared[layer]} != {want}"


def test_cnn_gemm_shapes_match_real_forward():
    """Run one real forward batch and verify the im2col operands have the
    declared artifact shapes."""
    (xtr, _), _ = cnn.make_dataset(seed=7, n_train=100, n_test=10)
    x = jnp.asarray(xtr[:100])
    params = cnn.init_params()
    declared = {layer: (m, k, n) for layer, m, k, n in aot.CNN_GEMMS}

    cols1 = cnn.im2col(x)
    assert (params["conv1_w"].shape[0], *cols1.shape) == declared["conv1"]
    h = jax.nn.relu(cnn.conv_gemm(params["conv1_w"], params["conv1_b"], x))
    h = cnn.maxpool2(h)
    cols2 = cnn.im2col(h)
    assert (params["conv2_w"].shape[0], *cols2.shape) == declared["conv2"]
    h = jax.nn.relu(cnn.conv_gemm(params["conv2_w"], params["conv2_b"], h))
    h = cnn.maxpool2(h)
    cols3 = cnn.im2col(h)
    assert (params["conv3_w"].shape[0], *cols3.shape) == declared["conv3"]


def test_artifact_names_unique_and_resolvable():
    specs = aot.build_specs()
    names = [s["name"] for s in specs]
    assert len(names) == len(set(names))
    kinds = {s["kind"] for s in specs}
    assert kinds == {"getnorm", "tilegemm", "dense", "spamm_fused", "tune"}
    # every tilegemm lonum has at least two batch buckets (greedy packing
    # in the Rust executor relies on a bucket ladder)
    for lonum in aot.LONUMS:
        buckets = [
            s["params"]["batch"]
            for s in specs
            if s["kind"] == "tilegemm"
            and s["params"]["lonum"] == lonum
            and s["params"]["precision"] == "f32"
        ]
        assert len(buckets) >= 2, f"lonum {lonum} needs a bucket ladder"


def test_tune_bdims_cover_square_grid():
    """Every (N, LoNum) combination the benches use must have a tuner."""
    specs = aot.build_specs()
    tune_bdims = {
        s["params"]["bdim"] for s in specs if s["kind"] == "tune"
    }
    for n in aot.SQUARE_SIZES:
        for lonum in aot.LONUMS:
            if n % lonum == 0:
                assert n // lonum in tune_bdims, (n, lonum)


def test_dense_baseline_covers_getnorm_grid():
    """Speedup tables need a dense artifact for every getnorm size."""
    specs = aot.build_specs()
    dense_ns = {
        s["params"]["n"]
        for s in specs
        if s["kind"] == "dense" and "layer" not in s["params"]
    }
    getnorm_ns = {s["params"]["n"] for s in specs if s["kind"] == "getnorm"}
    assert getnorm_ns <= dense_ns


def test_fused_spamm_equivalent_to_two_kernel_path():
    """The fused artifact graph must equal getnorm+multiply composed (the
    §3.1 'equivalent re-design' claim at the graph level)."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((128, 128)).astype(np.float32)
    b = rng.standard_normal((128, 128)).astype(np.float32)
    (na,) = model.getnorm_graph(a)
    (nb,) = model.getnorm_graph(b)
    tau = jnp.float32(float(np.median(np.asarray(na))) ** 2)
    from python.compile.kernels import spamm_multiply

    two_kernel = spamm_multiply(a, b, na, nb, tau, lonum=32, block=True)
    (fused,) = model.spamm_fused_graph(a, b, tau, lonum=32)
    np.testing.assert_array_equal(np.asarray(two_kernel), np.asarray(fused))
