# valid-ratio → τ search (§3.5.2) vs oracle; paper claims <1% ratio error
# within 20 iterations on its synthesized matrices.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from python.compile.kernels import get_norm
from python.compile.kernels.tune import tune_tau, valid_ratio
from python.compile.kernels import ref
from .conftest import decay_matrix


def normmaps(n=256, lonum=32, seeds=(1, 2)):
    a = decay_matrix(n, seed=seeds[0])
    b = decay_matrix(n, seed=seeds[1])
    return get_norm(a, lonum=lonum), get_norm(b, lonum=lonum)


@pytest.mark.parametrize("target", [0.30, 0.25, 0.20, 0.15, 0.10, 0.05])
def test_tune_hits_paper_ratios(target):
    """The six valid-ratio targets of Table 1, <1% absolute ratio error."""
    na, nb = normmaps()
    tau, ratio = tune_tau(na, nb, target, iters=20)
    assert abs(float(ratio) - target) < 0.01
    # achieved ratio must agree with the independent oracle
    assert ref.valid_ratio(np.asarray(na), np.asarray(nb), float(tau)) == (
        pytest.approx(float(ratio), abs=1e-6)
    )


def test_tune_ratio_one():
    """target=1 → τ must fall at/below the smallest norm product."""
    na, nb = normmaps()
    tau, ratio = tune_tau(na, nb, 1.0, iters=30)
    assert float(ratio) == pytest.approx(1.0, abs=0.01)


def test_valid_ratio_monotone():
    na, nb = normmaps()
    taus = np.linspace(0, float(np.asarray(na).max()) ** 2, 10)
    ratios = [float(valid_ratio(na, nb, t)) for t in taus]
    assert all(r1 >= r2 for r1, r2 in zip(ratios, ratios[1:]))
    assert ratios[0] == 1.0


def test_tune_expansion_phase():
    """A target so small that τ must exceed the mean product forces the
    §3.5.2 upper-bound expansion (k > 1) to engage."""
    na, nb = normmaps(n=512)
    tau, ratio = tune_tau(na, nb, 0.01, iters=30)
    prod = np.asarray(na)[:, :, None] * np.asarray(nb)[None, :, :]
    assert float(tau) > float(prod.mean())  # needed expansion past ave
    assert abs(float(ratio) - 0.01) < 0.01


@settings(max_examples=15, deadline=None)
@given(
    target=st.floats(0.02, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_tune_property(target, seed):
    rng = np.random.default_rng(seed)
    na = np.abs(rng.standard_normal((8, 8))).astype(np.float32)
    nb = np.abs(rng.standard_normal((8, 8))).astype(np.float32)
    tau, ratio = tune_tau(na, nb, target, iters=25)
    # Discrete product set (512 values) → quantization ~1/512 plus search
    # tolerance; paper's own bound is 1%.
    assert abs(float(ratio) - target) < 0.02
