# Batched tile-GEMM kernel (the coordinator's execution vehicle) vs oracle.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from python.compile.kernels import tile_gemm_batch
from python.compile.kernels import ref


@pytest.mark.parametrize("batch,lonum", [(1, 32), (7, 32), (64, 32), (16, 64)])
def test_tile_gemm_matches_ref(batch, lonum, rng):
    a = rng.standard_normal((batch, lonum, lonum)).astype(np.float32)
    b = rng.standard_normal((batch, lonum, lonum)).astype(np.float32)
    got = np.asarray(tile_gemm_batch(a, b))
    want = np.asarray(ref.tile_gemm_batch(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_tile_gemm_zero_padding_tail(rng):
    """Zero-padded tail tiles (the coordinator pads partial batches) must
    produce exactly-zero products and not pollute neighbours."""
    a = rng.standard_normal((4, 32, 32)).astype(np.float32)
    b = rng.standard_normal((4, 32, 32)).astype(np.float32)
    a[2:] = 0.0
    got = np.asarray(tile_gemm_batch(a, b))
    assert np.all(got[2:] == 0.0)
    np.testing.assert_allclose(
        got[:2], np.asarray(ref.tile_gemm_batch(a[:2], b[:2])), rtol=1e-5
    )


def test_tile_gemm_bf16_accumulates_f32(rng):
    """bf16 path: output dtype f32, relative error within bf16 bounds."""
    a = rng.standard_normal((8, 32, 32)).astype(np.float32)
    b = rng.standard_normal((8, 32, 32)).astype(np.float32)
    got = np.asarray(tile_gemm_batch(a, b, precision="bf16"))
    want = np.asarray(ref.tile_gemm_batch(a, b))
    assert got.dtype == np.float32
    denom = np.abs(want) + 1.0
    assert np.max(np.abs(got - want) / denom) < 0.05


def test_tile_gemm_shape_mismatch_raises(rng):
    a = rng.standard_normal((4, 32, 32)).astype(np.float32)
    b = rng.standard_normal((5, 32, 32)).astype(np.float32)
    with pytest.raises(ValueError):
        tile_gemm_batch(a, b)


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 16),
    lonum=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tile_gemm_property(batch, lonum, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((batch, lonum, lonum)).astype(np.float32)
    b = rng.standard_normal((batch, lonum, lonum)).astype(np.float32)
    got = np.asarray(tile_gemm_batch(a, b))
    want = np.asarray(ref.tile_gemm_batch(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
