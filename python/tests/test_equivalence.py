# The paper's §3.1 claim: the flat two-kernel cuSpAMM re-design is
# equivalent to the original recursive SpAMM (Algorithm 1) "because they
# both perform calculation on the sub-matrices that satisfy the F-norm
# threshold".  Strictly, the recursion also prunes *interior* nodes whose
# aggregated norms fall under τ, so the flat algorithm performs a superset
# of the recursive algorithm's work; equivalence is exact at the leaf level
# when no interior pruning triggers.  These tests pin down both facts.
import numpy as np
import pytest

from python.compile.kernels import get_norm, spamm_multiply
from python.compile.kernels import ref
from .conftest import decay_matrix


def flat(a, b, tau, lonum):
    na = get_norm(a, lonum=lonum)
    nb = get_norm(b, lonum=lonum)
    return np.asarray(spamm_multiply(a, b, na, nb, tau, lonum=lonum))


def test_flat_equals_recursive_tau_zero():
    a = decay_matrix(128, seed=11)
    b = decay_matrix(128, seed=12)
    np.testing.assert_allclose(
        flat(a, b, 0.0, 32), ref.spamm_recursive(a, b, 0.0, 32),
        rtol=1e-4, atol=1e-5,
    )


@pytest.mark.parametrize("lonum", [16, 32])
def test_flat_equals_recursive_moderate_tau(lonum):
    """For thresholds below every interior norm product the recursion never
    prunes an interior node, and flat ≡ recursive exactly."""
    a = decay_matrix(128, seed=13)
    b = decay_matrix(128, seed=14)
    # Interior norms only grow as tiles aggregate, so a τ chosen at leaf
    # scale (< min leaf product that matters) keeps interior tests passing.
    na = np.asarray(ref.tile_norms(a, lonum))
    nb = np.asarray(ref.tile_norms(b, lonum))
    tau = float(np.percentile(na[:, :, None] * nb[None, :, :], 30))
    f = flat(a, b, tau, lonum)
    r = ref.spamm_recursive(a, b, tau, lonum)
    np.testing.assert_allclose(f, r, rtol=1e-4, atol=1e-5)


def test_flat_error_at_most_recursive_error():
    """Flat skips a subset of what recursion skips (interior pruning skips
    more) → ‖E_flat‖ ≤ ‖E_rec‖ for the same τ."""
    a = decay_matrix(256, kind="exponential", c=1.0, lam=0.45, noise=True, seed=15)
    b = decay_matrix(256, kind="exponential", c=1.0, lam=0.45, noise=True, seed=16)
    exact = a.astype(np.float64) @ b.astype(np.float64)
    for tau in [1e-3, 1e-2, 1e-1]:
        ef = np.linalg.norm(exact - flat(a, b, tau, 32))
        er = np.linalg.norm(exact - ref.spamm_recursive(a, b, tau, 32))
        assert ef <= er + 1e-3, (tau, ef, er)


def test_error_bound_artemov():
    """Artemov's bound for exponential-decay inputs:
    ‖E‖_F = O(N^{1/2} · τ^{p/2}), p < 2 — i.e. error vanishes with τ and the
    τ-scaling exponent stays below 1 in log-log slope."""
    a = decay_matrix(256, kind="exponential", c=1.0, lam=0.5, noise=True, seed=17)
    b = decay_matrix(256, kind="exponential", c=1.0, lam=0.5, noise=True, seed=18)
    exact = a.astype(np.float64) @ b.astype(np.float64)
    taus = np.array([1e-4, 1e-3, 1e-2])
    errs = np.array(
        [max(np.linalg.norm(exact - flat(a, b, t, 32)), 1e-12) for t in taus]
    )
    assert np.all(np.diff(errs) >= 0)  # monotone
    # log-log slope bounded by p/2 < 1 on the growing section
    grow = errs > 1e-9
    if grow.sum() >= 2:
        slopes = np.diff(np.log(errs[grow])) / np.diff(np.log(taus[grow]))
        assert np.all(slopes < 1.5)  # p/2 < 1 with sampling slack
