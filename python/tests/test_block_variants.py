# The exported artifacts use single-program ("block") kernel variants
# because interpret-mode grid steps cost ~2 ms each on CPU-PJRT
# (DESIGN.md §Perf).  These tests pin the contract: block ≡ grid variant
# numerically (exactly for f32 paths, within bf16 tolerance for MXU paths).
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from python.compile.kernels import (
    get_norm,
    get_norm_mxu,
    spamm_multiply,
    tile_gemm_batch,
)
from python.compile.kernels import ref
from .conftest import decay_matrix


def test_get_norm_block_equals_grid(rng):
    a = rng.standard_normal((128, 128)).astype(np.float32)
    g = np.asarray(get_norm(a, lonum=32))
    b = np.asarray(get_norm(a, lonum=32, block=True))
    np.testing.assert_array_equal(g, b)


def test_get_norm_mxu_block_close_to_exact():
    a = decay_matrix(128, seed=31)
    exact = np.asarray(ref.tile_norms(a, 32))
    b = np.asarray(get_norm_mxu(a, lonum=32, block=True))
    np.testing.assert_allclose(b, exact, rtol=2e-2, atol=1e-4)


def test_multiply_block_equals_grid(rng):
    a = decay_matrix(128, seed=32)
    b = decay_matrix(128, seed=33)
    na = get_norm(a, lonum=32)
    nb = get_norm(b, lonum=32)
    tau = float(np.median(np.asarray(na))) ** 2
    cg = np.asarray(spamm_multiply(a, b, na, nb, tau, lonum=32))
    cb = np.asarray(spamm_multiply(a, b, na, nb, tau, lonum=32, block=True))
    np.testing.assert_array_equal(cg, cb)


def test_tile_gemm_block_equals_grid(rng):
    at = rng.standard_normal((9, 32, 32)).astype(np.float32)
    bt = rng.standard_normal((9, 32, 32)).astype(np.float32)
    g = np.asarray(tile_gemm_batch(at, bt))
    b = np.asarray(tile_gemm_batch(at, bt, block=True))
    np.testing.assert_array_equal(g, b)


def test_tile_gemm_block_bf16_close(rng):
    at = rng.standard_normal((4, 32, 32)).astype(np.float32)
    bt = rng.standard_normal((4, 32, 32)).astype(np.float32)
    want = np.asarray(ref.tile_gemm_batch(at, bt))
    got = np.asarray(tile_gemm_batch(at, bt, precision="bf16", block=True))
    assert np.max(np.abs(got - want) / (np.abs(want) + 1.0)) < 0.05


@settings(max_examples=10, deadline=None)
@given(
    bdim=st.integers(1, 3),
    lonum=st.sampled_from([8, 16, 32]),
    tau_scale=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_multiply_block_property(bdim, lonum, tau_scale, seed):
    rng = np.random.default_rng(seed)
    n = bdim * lonum
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    na = get_norm(a, lonum=lonum, block=True)
    nb = get_norm(b, lonum=lonum, block=True)
    tau = float(np.mean(np.asarray(na)) ** 2) * tau_scale
    got = np.asarray(spamm_multiply(a, b, na, nb, tau, lonum=lonum, block=True))
    want = np.asarray(ref.spamm_flat(a, b, tau, lonum))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
