# multiplication kernel (masked SpAMM) vs oracle.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from python.compile.kernels import get_norm, spamm_multiply
from python.compile.kernels import ref
from .conftest import decay_matrix


def run_spamm(a, b, tau, lonum, precision="f32"):
    na = get_norm(a, lonum=lonum)
    nb = get_norm(b, lonum=lonum)
    return np.asarray(
        spamm_multiply(a, b, na, nb, tau, lonum=lonum, precision=precision)
    )


@pytest.mark.parametrize("n,lonum", [(64, 32), (128, 32), (128, 64), (256, 32)])
def test_multiply_matches_ref(n, lonum, rng):
    a = decay_matrix(n, seed=1)
    b = decay_matrix(n, seed=2)
    nm = np.asarray(ref.tile_norms(a, lonum))
    tau = float(np.median(nm)) ** 2
    got = run_spamm(a, b, tau, lonum)
    want = np.asarray(ref.spamm_flat(a, b, tau, lonum))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_multiply_tau_zero_is_dense(rng):
    """τ=0: every tile product valid → exact dense GEMM."""
    a = rng.standard_normal((128, 128)).astype(np.float32)
    b = rng.standard_normal((128, 128)).astype(np.float32)
    got = run_spamm(a, b, 0.0, 32)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


def test_multiply_tau_huge_is_zero(rng):
    """τ→∞: nothing passes → C = 0."""
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    got = run_spamm(a, b, 1e30, 32)
    assert np.all(got == 0.0)


def test_multiply_error_monotone_in_tau():
    """‖E(τ)‖_F is non-decreasing in τ (more skipping, more error)."""
    a = decay_matrix(128, seed=5)
    b = decay_matrix(128, seed=6)
    exact = np.asarray(a @ b, np.float32)
    errs = []
    for tau in [0.0, 1e-4, 1e-3, 1e-2, 1e-1]:
        c = run_spamm(a, b, tau, 32)
        errs.append(float(np.linalg.norm(exact - c)))
    assert errs == sorted(errs)
    assert errs[0] < 1e-3  # τ=0 exact


def test_multiply_bf16_close():
    """Tensor-core analog: bf16 operands, f32 accumulate → ~2 digit accuracy."""
    a = decay_matrix(128, seed=7)
    b = decay_matrix(128, seed=8)
    f32_res = run_spamm(a, b, 0.0, 32, precision="f32")
    bf16_res = run_spamm(a, b, 0.0, 32, precision="bf16")
    denom = np.linalg.norm(f32_res) + 1e-30
    assert np.linalg.norm(f32_res - bf16_res) / denom < 2e-2


def test_multiply_skips_decayed_offdiagonal():
    """On a strongly decayed matrix a moderate τ must leave C ≈ exact near
    the diagonal while skipping far-off-diagonal work entirely."""
    a = decay_matrix(256, kind="exponential", c=1.0, lam=0.3, noise=False)
    b = a.copy()
    nm = np.asarray(ref.tile_norms(a, 32))
    tau = float(nm[0, -1] * nm.max()) * 10.0  # above corner-tile products
    got = run_spamm(a, b, tau, 32)
    exact = a @ b
    # diagonal block almost exact
    np.testing.assert_allclose(got[:32, :32], exact[:32, :32], rtol=1e-2)
    # global error small relative to result
    assert np.linalg.norm(exact - got) / np.linalg.norm(exact) < 1e-2


@settings(max_examples=15, deadline=None)
@given(
    bdim=st.integers(1, 4),
    tau_scale=st.floats(0.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_multiply_property(bdim, tau_scale, seed):
    """Kernel ≡ flat oracle for arbitrary shapes and thresholds."""
    lonum = 16
    n = bdim * lonum
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    nm = np.asarray(ref.tile_norms(a, lonum))
    tau = float(np.mean(nm) ** 2) * tau_scale
    got = run_spamm(a, b, tau, lonum)
    want = np.asarray(ref.spamm_flat(a, b, tau, lonum))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
